//! Fleet-wide telemetry: a process-global metrics registry and a
//! lock-light ring-buffer journal of job-lifecycle events.
//!
//! The paper's pitch is a *measurable* resource/convergence trade, so
//! the stack that reproduces it has to be able to observe itself. This
//! module is the dependency-free spine: every layer (gateway, queue,
//! worker pool, remote agents, training core) increments the same
//! static counters/gauges/histograms, and the gateway surfaces them as
//! Prometheus text exposition (`GET /metrics`), a JSON event tail
//! (`GET /events?n=K`), and per-phase summaries folded into `/stats`.
//!
//! Design constraints:
//!
//! * **No dependencies, no registration ceremony.** Metrics are
//!   `static` atomics; the registry is the [`families`] table that
//!   names them for exposition. Incrementing a counter is one relaxed
//!   atomic op — safe in the training hot loop.
//! * **Process-global.** The gateway, a worker agent, and a local
//!   trainer are separate processes; each sees its own registry. The
//!   gateway additionally aggregates *worker-reported* per-phase
//!   timings (sync/run, carried in the `/work/<seq>/result` body) into
//!   its own histograms, so one scrape of the gateway shows fleet-wide
//!   latency.
//! * **Fixed-bucket histograms.** Cumulative `le` buckets with a
//!   static bound table; percentile readout (p50/p95/p99) returns the
//!   upper bound of the bucket the rank lands in — an estimate that
//!   never allocates and never locks.
//! * **Lock-light journal.** One short [`Mutex`] around a fixed-size
//!   ring of structured [`Event`]s (enqueue → lease → sync → run →
//!   report). Capacity 0 disables it entirely (`--metrics summary`).

use crate::metrics::format_g;
use crate::util::json::escape_str as esc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

// ---------------------------------------------------------------------
// Primitives
// ---------------------------------------------------------------------

/// Monotonically increasing counter (`*_total` families).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Self(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins f64 gauge (stored as bits in one atomic word).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        // f64 0.0 is all-zero bits, so the const zero word is exact.
        Self(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Upper bound on histogram bucket-table length (static storage).
pub const MAX_BUCKETS: usize = 20;

/// Fixed-bucket latency histogram: cumulative-on-read `le` buckets,
/// a nanosecond-resolution sum, and rank-based percentile readout.
pub struct Histogram {
    bounds: &'static [f64],
    buckets: [AtomicU64; MAX_BUCKETS],
    /// Observations above the last finite bound (`le="+Inf"` overflow).
    overflow: AtomicU64,
    sum_nanos: AtomicU64,
    count: AtomicU64,
}

/// General request/job latency bounds: 1 ms … 60 s.
pub const LATENCY_BOUNDS: &[f64] = &[
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0, 10.0, 30.0, 60.0,
];

/// Hot-loop bounds for training steps and mask refreshes: 1 µs … 1 s.
pub const FAST_BOUNDS: &[f64] = &[
    1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3,
    2.5e-3, 5e-3, 1e-2, 0.05, 0.1, 0.5, 1.0,
];

/// Dimensionless ratio bounds (≥ 1.0) for load-balance histograms:
/// 1.0 is perfect, anything past ~2 means one shard does double duty.
pub const RATIO_BOUNDS: &[f64] = &[
    1.0, 1.01, 1.02, 1.05, 1.1, 1.2, 1.35, 1.5, 1.75, 2.0, 3.0, 5.0,
];

impl Histogram {
    pub const fn new(bounds: &'static [f64]) -> Self {
        assert!(bounds.len() <= MAX_BUCKETS);
        const Z: AtomicU64 = AtomicU64::new(0);
        Self {
            bounds,
            buckets: [Z; MAX_BUCKETS],
            overflow: Z,
            sum_nanos: Z,
            count: Z,
        }
    }

    /// Record one observation, in seconds. Negative or NaN values are
    /// clamped to zero (a clock hiccup must not poison the series).
    pub fn observe(&self, secs: f64) {
        let v = if secs.is_finite() && secs > 0.0 { secs } else { 0.0 };
        match self.bounds.iter().position(|&b| v <= b) {
            Some(i) => self.buckets[i].fetch_add(1, Ordering::Relaxed),
            None => self.overflow.fetch_add(1, Ordering::Relaxed),
        };
        self.sum_nanos
            .fetch_add((v * 1e9) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_secs() / n as f64
        }
    }

    /// Cumulative `(upper_bound, count)` pairs, ending with the
    /// `+Inf` bucket (whose count equals [`Self::count`]).
    pub fn cumulative(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.bounds.len() + 1);
        let mut cum = 0u64;
        for (i, &b) in self.bounds.iter().enumerate() {
            cum += self.buckets[i].load(Ordering::Relaxed);
            out.push((b, cum));
        }
        cum += self.overflow.load(Ordering::Relaxed);
        out.push((f64::INFINITY, cum));
        out
    }

    /// Rank-based percentile estimate (`p` in [0, 100]): the upper
    /// bound of the bucket the nearest-rank observation falls in.
    /// Observations beyond the last finite bound report that bound
    /// (the histogram does not retain exact maxima). Returns 0.0 for
    /// an empty histogram.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * n as f64).ceil()
            as u64;
        let rank = rank.max(1);
        let mut cum = 0u64;
        for (i, &b) in self.bounds.iter().enumerate() {
            cum += self.buckets[i].load(Ordering::Relaxed);
            if cum >= rank {
                return b;
            }
        }
        *self.bounds.last().unwrap_or(&0.0)
    }

    /// `{"count":N,"mean":..,"p50":..,"p95":..,"p99":..}` — the
    /// summary block `/stats` folds in per phase.
    pub fn summary_json(&self) -> String {
        format!(
            "{{\"count\":{},\"mean\":{},\"p50\":{},\"p95\":{},\
             \"p99\":{}}}",
            self.count(),
            format_g(self.mean_secs()),
            format_g(self.percentile(50.0)),
            format_g(self.percentile(95.0)),
            format_g(self.percentile(99.0)),
        )
    }
}

// ---------------------------------------------------------------------
// The registry: static metrics + the family table that names them
// ---------------------------------------------------------------------

// Gateway (HTTP front door).
pub static HTTP_CONNECTIONS: Counter = Counter::new();
pub static HTTP_REQUESTS: Counter = Counter::new();
pub static HTTP_THROTTLED: Counter = Counter::new();
pub static HTTP_REFUSED: Counter = Counter::new();

// Queue.
pub static QUEUE_DEPTH: Gauge = Gauge::new();
pub static JOBS_SUBMITTED: Counter = Counter::new();
pub static QUEUE_WAIT_SECONDS: Histogram =
    Histogram::new(LATENCY_BOUNDS);

// Jobs / workers.
pub static JOBS_COMPLETED: Counter = Counter::new();
pub static JOBS_FAILED: Counter = Counter::new();
pub static CACHE_HITS: Counter = Counter::new();
pub static LEASES_GRANTED: Counter = Counter::new();
pub static LEASES_EXPIRED: Counter = Counter::new();
pub static SYNC_SECONDS: Histogram = Histogram::new(LATENCY_BOUNDS);
pub static RUN_SECONDS: Histogram = Histogram::new(LATENCY_BOUNDS);
pub static CACHE_HIT_SECONDS: Histogram =
    Histogram::new(LATENCY_BOUNDS);

// Training core.
pub static STEP_SECONDS: Histogram = Histogram::new(FAST_BOUNDS);
pub static MASK_REFRESH_SECONDS: Histogram =
    Histogram::new(FAST_BOUNDS);
pub static STATE_BYTES: Gauge = Gauge::new();
pub static KEEP_RATIO: Gauge = Gauge::new();
/// Dense→runs mask scans (`MaskRuns::from_dense`). Cold path by
/// contract: stays 0 across a steady-state train run — a nonzero rate
/// during training is a densification regression.
pub static MASK_DENSIFY: Counter = Counter::new();

// Parallel execution engine (omgd-core::exec).
/// Threads the step engine currently runs with (caller included).
pub static STEP_THREADS: Gauge = Gauge::new();
/// Active-coordinate load imbalance of the current shard partition
/// (max shard over mean; 1.0 = perfectly balanced). Observed when a
/// mask refresh re-partitions, not per step.
pub static EXEC_SHARD_IMBALANCE: Histogram =
    Histogram::new(RATIO_BOUNDS);
/// Wall time of one shard task inside a parallel region.
pub static EXEC_SHARD_SECONDS: Histogram = Histogram::new(FAST_BOUNDS);

// Durability: job journal + train checkpoints.
pub static JOURNAL_RECORDS: Counter = Counter::new();
pub static JOURNAL_REPLAYED: Counter = Counter::new();
pub static JOURNAL_TORN: Counter = Counter::new();
pub static JOURNAL_COMPACTIONS: Counter = Counter::new();
pub static CKPT_WRITES: Counter = Counter::new();
pub static CKPT_RESUMES: Counter = Counter::new();
pub static CKPT_PARKED: Counter = Counter::new();

/// A named metric for exposition.
pub enum Metric {
    C(&'static Counter),
    G(&'static Gauge),
    H(&'static Histogram),
}

/// One exposition family: name, HELP text, and the backing metric.
pub struct Family {
    pub name: &'static str,
    pub help: &'static str,
    pub metric: Metric,
}

/// The full registry, in exposition order. Every metric the process
/// owns is listed here — `GET /metrics` is exactly this table.
pub fn families() -> Vec<Family> {
    use Metric::{C, G, H};
    vec![
        Family {
            name: "omgd_http_connections_total",
            help: "TCP connections accepted by the gateway",
            metric: C(&HTTP_CONNECTIONS),
        },
        Family {
            name: "omgd_http_requests_total",
            help: "HTTP requests handled (all endpoints)",
            metric: C(&HTTP_REQUESTS),
        },
        Family {
            name: "omgd_http_throttled_total",
            help: "Requests rejected 429 (queue saturation or client \
                   quota)",
            metric: C(&HTTP_THROTTLED),
        },
        Family {
            name: "omgd_http_refused_total",
            help: "Connections refused 503 (connection cap or drain)",
            metric: C(&HTTP_REFUSED),
        },
        Family {
            name: "omgd_queue_depth",
            help: "Jobs currently waiting in the priority queue",
            metric: G(&QUEUE_DEPTH),
        },
        Family {
            name: "omgd_jobs_submitted_total",
            help: "Jobs accepted into the queue",
            metric: C(&JOBS_SUBMITTED),
        },
        Family {
            name: "omgd_queue_wait_seconds",
            help: "Enqueue-to-dispatch wait per job",
            metric: H(&QUEUE_WAIT_SECONDS),
        },
        Family {
            name: "omgd_jobs_completed_total",
            help: "Jobs finished with status done",
            metric: C(&JOBS_COMPLETED),
        },
        Family {
            name: "omgd_jobs_failed_total",
            help: "Jobs finished failed or panicked",
            metric: C(&JOBS_FAILED),
        },
        Family {
            name: "omgd_cache_hits_total",
            help: "Jobs answered from a result cache",
            metric: C(&CACHE_HITS),
        },
        Family {
            name: "omgd_leases_granted_total",
            help: "Work leases granted to remote workers",
            metric: C(&LEASES_GRANTED),
        },
        Family {
            name: "omgd_leases_expired_total",
            help: "Leases that expired and were requeued",
            metric: C(&LEASES_EXPIRED),
        },
        Family {
            name: "omgd_artifact_sync_seconds",
            help: "Artifact-set download+unpack time (worker-reported)",
            metric: H(&SYNC_SECONDS),
        },
        Family {
            name: "omgd_job_run_seconds",
            help: "Job execution time, cache hits excluded",
            metric: H(&RUN_SECONDS),
        },
        Family {
            name: "omgd_cache_hit_seconds",
            help: "End-to-end latency of cache-served jobs",
            metric: H(&CACHE_HIT_SECONDS),
        },
        Family {
            name: "omgd_train_step_seconds",
            help: "Optimizer step duration (engine apply)",
            metric: H(&STEP_SECONDS),
        },
        Family {
            name: "omgd_mask_refresh_seconds",
            help: "Mask refresh duration at period boundaries",
            metric: H(&MASK_REFRESH_SECONDS),
        },
        Family {
            name: "omgd_train_state_bytes",
            help: "Live optimizer state bytes under the current mask",
            metric: G(&STATE_BYTES),
        },
        Family {
            name: "omgd_train_keep_ratio",
            help: "Active fraction of the current mask",
            metric: G(&KEEP_RATIO),
        },
        Family {
            name: "omgd_mask_densify_total",
            help: "Dense-to-runs mask scans (cold path; nonzero rate \
                   during training is a densification regression)",
            metric: C(&MASK_DENSIFY),
        },
        Family {
            name: "omgd_step_threads",
            help: "Threads the parallel step engine runs with \
                   (caller included)",
            metric: G(&STEP_THREADS),
        },
        Family {
            name: "omgd_exec_shard_imbalance",
            help: "Shard active-count imbalance (max/mean) of the \
                   current partition, observed at mask refresh",
            metric: H(&EXEC_SHARD_IMBALANCE),
        },
        Family {
            name: "omgd_exec_shard_seconds",
            help: "Wall time of one shard task inside a parallel \
                   region",
            metric: H(&EXEC_SHARD_SECONDS),
        },
        Family {
            name: "omgd_journal_records_total",
            help: "Records appended to the durable job journal",
            metric: C(&JOURNAL_RECORDS),
        },
        Family {
            name: "omgd_journal_replayed_total",
            help: "Journal records replayed at startup",
            metric: C(&JOURNAL_REPLAYED),
        },
        Family {
            name: "omgd_journal_torn_total",
            help: "Torn or corrupt journal tail records dropped on \
                   replay",
            metric: C(&JOURNAL_TORN),
        },
        Family {
            name: "omgd_journal_compactions_total",
            help: "Journal compaction passes (startup and clean \
                   shutdown)",
            metric: C(&JOURNAL_COMPACTIONS),
        },
        Family {
            name: "omgd_ckpt_writes_total",
            help: "Training checkpoints written",
            metric: C(&CKPT_WRITES),
        },
        Family {
            name: "omgd_ckpt_resumes_total",
            help: "Training runs resumed from a checkpoint",
            metric: C(&CKPT_RESUMES),
        },
        Family {
            name: "omgd_ckpt_parked_total",
            help: "Checkpoints parked on lease expiry or report \
                   failure",
            metric: C(&CKPT_PARKED),
        },
    ]
}

/// Render families as Prometheus text exposition (format 0.0.4).
pub fn render(families: &[Family]) -> String {
    let mut out = String::new();
    for f in families {
        let kind = match f.metric {
            Metric::C(_) => "counter",
            Metric::G(_) => "gauge",
            Metric::H(_) => "histogram",
        };
        out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
        out.push_str(&format!("# TYPE {} {}\n", f.name, kind));
        match f.metric {
            Metric::C(c) => {
                out.push_str(&format!("{} {}\n", f.name, c.get()));
            }
            Metric::G(g) => {
                out.push_str(&format!(
                    "{} {}\n",
                    f.name,
                    format_g(g.get())
                ));
            }
            Metric::H(h) => {
                for (bound, cum) in h.cumulative() {
                    let le = if bound.is_infinite() {
                        "+Inf".to_string()
                    } else {
                        format_g(bound)
                    };
                    out.push_str(&format!(
                        "{}_bucket{{le=\"{le}\"}} {cum}\n",
                        f.name
                    ));
                }
                out.push_str(&format!(
                    "{}_sum {}\n",
                    f.name,
                    format_g(h.sum_secs())
                ));
                out.push_str(&format!(
                    "{}_count {}\n",
                    f.name,
                    h.count()
                ));
            }
        }
    }
    out
}

/// The whole process registry as one scrape body.
pub fn render_prometheus() -> String {
    render(&families())
}

// ---------------------------------------------------------------------
// Event journal
// ---------------------------------------------------------------------

/// Default ring capacity (`--metrics` can resize or disable it).
pub const DEFAULT_JOURNAL_CAP: usize = 512;

/// One structured job-lifecycle span. `kind` is the span name
/// (`enqueue`, `lease`, `sync`, `run`, `report`); unknown identity
/// fields stay empty, unknown durations stay 0.
#[derive(Clone, Debug, Default)]
pub struct Event {
    pub kind: &'static str,
    pub seq: u64,
    /// Spec content hash (hex).
    pub hash: String,
    /// Fairness/client token the job was submitted under.
    pub client: String,
    /// Worker id that held the lease (remote) or `local`.
    pub worker: String,
    /// Enqueue → lease/dispatch wait.
    pub queue_secs: f64,
    /// Artifact sync time, as reported by the worker.
    pub sync_secs: f64,
    /// Execution time, cache replays excluded.
    pub run_secs: f64,
    /// End-to-end span total.
    pub secs: f64,
}

impl Event {
    pub fn new(kind: &'static str, seq: u64) -> Self {
        Self { kind, seq, ..Self::default() }
    }

    fn render(&self, id: u64, ts_ms: u64) -> String {
        format!(
            "{{\"id\":{id},\"ts_ms\":{ts_ms},\"kind\":\"{}\",\
             \"seq\":{},\"hash\":\"{}\",\"client\":\"{}\",\
             \"worker\":\"{}\",\"queue_secs\":{},\"sync_secs\":{},\
             \"run_secs\":{},\"secs\":{}}}",
            esc(self.kind),
            self.seq,
            esc(&self.hash),
            esc(&self.client),
            esc(&self.worker),
            format_g(self.queue_secs),
            format_g(self.sync_secs),
            format_g(self.run_secs),
            format_g(self.secs),
        )
    }
}

struct JournalInner {
    /// Ring storage: grows to `cap`, then overwrites at `write`.
    buf: Vec<(u64, u64, Event)>,
    write: usize,
    next_id: u64,
}

/// Fixed-capacity ring of [`Event`]s behind one short mutex. Pushes
/// are O(1) and never block on readers for longer than a tail copy.
pub struct Journal {
    inner: Mutex<JournalInner>,
    /// Capacity is read on the push fast path without the lock so a
    /// disabled journal (cap 0) costs one atomic load per event.
    cap: AtomicUsize,
    dropped: AtomicU64,
}

static JOURNAL: Journal = Journal {
    inner: Mutex::new(JournalInner {
        buf: Vec::new(),
        write: 0,
        next_id: 0,
    }),
    cap: AtomicUsize::new(DEFAULT_JOURNAL_CAP),
    dropped: AtomicU64::new(0),
};

/// The process-global journal.
pub fn journal() -> &'static Journal {
    &JOURNAL
}

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

impl Journal {
    /// Resize the ring (0 disables). Existing events are retained
    /// oldest-first up to the new capacity.
    pub fn set_capacity(&self, cap: usize) {
        let mut g = lock(&self.inner);
        let kept = self.snapshot_locked(&g);
        self.cap.store(cap, Ordering::Relaxed);
        g.buf.clear();
        g.write = 0;
        let skip = kept.len().saturating_sub(cap);
        for e in kept.into_iter().skip(skip) {
            g.buf.push(e);
        }
        if cap > 0 {
            g.write = g.buf.len() % cap;
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap.load(Ordering::Relaxed)
    }

    /// Events evicted by ring wrap since process start.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Append one event (no-op when disabled).
    pub fn push(&self, ev: Event) {
        let cap = self.capacity();
        if cap == 0 {
            return;
        }
        let mut g = lock(&self.inner);
        let id = g.next_id;
        g.next_id += 1;
        let entry = (id, now_ms(), ev);
        if g.buf.len() < cap {
            g.buf.push(entry);
            g.write = g.buf.len() % cap;
        } else {
            let w = g.write;
            g.buf[w] = entry;
            g.write = (w + 1) % cap;
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// In-order snapshot (oldest → newest) under the lock.
    fn snapshot_locked(
        &self,
        g: &JournalInner,
    ) -> Vec<(u64, u64, Event)> {
        if g.buf.len() < self.capacity() || g.buf.is_empty() {
            g.buf.clone()
        } else {
            let mut out = Vec::with_capacity(g.buf.len());
            out.extend_from_slice(&g.buf[g.write..]);
            out.extend_from_slice(&g.buf[..g.write]);
            out
        }
    }

    /// The last `n` events, oldest first, rendered as JSON lines.
    pub fn tail(&self, n: usize) -> Vec<String> {
        let snap = {
            let g = lock(&self.inner);
            self.snapshot_locked(&g)
        };
        let skip = snap.len().saturating_sub(n);
        snap[skip..]
            .iter()
            .map(|(id, ts, ev)| ev.render(*id, *ts))
            .collect()
    }

    /// Total events ever pushed.
    pub fn pushed(&self) -> u64 {
        lock(&self.inner).next_id
    }
}

/// Journal lock, recovering from poison — telemetry must never take a
/// worker thread down with it.
fn lock(m: &Mutex<JournalInner>) -> std::sync::MutexGuard<'_, JournalInner> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

// ---------------------------------------------------------------------
// Exposition verbosity
// ---------------------------------------------------------------------

/// `--metrics` knob: how much telemetry the gateway serves.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MetricsLevel {
    /// `/metrics` and `/events` return 404; journal disabled.
    Off,
    /// `/metrics` served; journal disabled, `/events` returns 404.
    Summary,
    /// Everything on (the default).
    #[default]
    Full,
}

impl MetricsLevel {
    pub fn as_str(&self) -> &'static str {
        match self {
            MetricsLevel::Off => "off",
            MetricsLevel::Summary => "summary",
            MetricsLevel::Full => "full",
        }
    }
}

impl std::str::FromStr for MetricsLevel {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "off" => Ok(MetricsLevel::Off),
            "summary" => Ok(MetricsLevel::Summary),
            "full" => Ok(MetricsLevel::Full),
            other => Err(anyhow::anyhow!(
                "unknown metrics level {other:?} \
                 (expected off|summary|full)"
            )),
        }
    }
}

// ---------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------

/// Parsed `OMGD_FAULT=<name>[:<nth>]` spec: kill the process at the
/// `nth` (1-based) hit of the named [`faultpoint`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    pub name: String,
    pub nth: u64,
}

/// Parse a fault spec: `"journal.append"` → first hit,
/// `"ckpt.write:3"` → third hit. Empty or malformed specs (bad count,
/// count 0, missing name) disable injection rather than erroring — a
/// stray env var must never take down a production process.
pub fn parse_fault_spec(raw: &str) -> Option<FaultSpec> {
    let raw = raw.trim();
    if raw.is_empty() {
        return None;
    }
    let (name, nth) = match raw.rsplit_once(':') {
        Some((n, c)) => (n.trim(), c.trim().parse::<u64>().ok()?),
        None => (raw, 1),
    };
    if name.is_empty() || nth == 0 {
        return None;
    }
    Some(FaultSpec { name: name.to_string(), nth })
}

static FAULT: OnceLock<Option<FaultSpec>> = OnceLock::new();
static FAULT_HITS: AtomicU64 = AtomicU64::new(0);

fn fault_spec() -> &'static Option<FaultSpec> {
    FAULT.get_or_init(|| {
        std::env::var("OMGD_FAULT")
            .ok()
            .and_then(|v| parse_fault_spec(&v))
    })
}

/// Crash-at-this-instant hook for durability tests. Named points are
/// threaded through the nastiest write windows (journal append,
/// checkpoint write, lease report, artifact publish); when
/// `OMGD_FAULT=<name>[:<nth>]` matches, the nth hit aborts the process
/// — the closest portable stand-in for SIGKILL (no destructors, no
/// flushes). A no-op (one lazy env read, then one branch) otherwise.
pub fn faultpoint(name: &str) {
    let Some(spec) = fault_spec() else { return };
    if spec.name != name {
        return;
    }
    let hit = FAULT_HITS.fetch_add(1, Ordering::Relaxed) + 1;
    if hit == spec.nth {
        eprintln!("omgd: faultpoint {name:?} hit {hit}, aborting");
        std::process::abort();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_read_back() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(0.25);
        assert_eq!(g.get(), 0.25);
        g.set(-3.5);
        assert_eq!(g.get(), -3.5);
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_end_at_count() {
        static BOUNDS: &[f64] = &[0.1, 1.0, 10.0];
        let h = Histogram::new(BOUNDS);
        for v in [0.05, 0.5, 0.5, 5.0, 50.0] {
            h.observe(v);
        }
        let cum = h.cumulative();
        assert_eq!(
            cum,
            vec![
                (0.1, 1),
                (1.0, 3),
                (10.0, 4),
                (f64::INFINITY, 5)
            ]
        );
        // monotone non-decreasing, +Inf == count
        for w in cum.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(cum.last().unwrap().1, h.count());
        assert!((h.sum_secs() - 56.05).abs() < 1e-6);
    }

    #[test]
    fn histogram_percentiles_are_bucket_upper_bounds() {
        static BOUNDS: &[f64] = &[0.1, 1.0, 10.0];
        let h = Histogram::new(BOUNDS);
        assert_eq!(h.percentile(50.0), 0.0); // empty
        // 10 obs: 5 in le=0.1, 4 in le=1, 1 overflow
        for _ in 0..5 {
            h.observe(0.05);
        }
        for _ in 0..4 {
            h.observe(0.5);
        }
        h.observe(99.0);
        assert_eq!(h.percentile(0.0), 0.1); // rank clamps to 1
        assert_eq!(h.percentile(50.0), 0.1); // rank 5 → first bucket
        assert_eq!(h.percentile(90.0), 1.0); // rank 9 → second bucket
        // rank 10 lands in overflow → last finite bound
        assert_eq!(h.percentile(99.0), 10.0);
        assert_eq!(h.percentile(100.0), 10.0);
    }

    #[test]
    fn histogram_clamps_garbage_observations() {
        static BOUNDS: &[f64] = &[1.0];
        let h = Histogram::new(BOUNDS);
        h.observe(f64::NAN);
        h.observe(-5.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.cumulative()[0], (1.0, 2));
        assert_eq!(h.sum_secs(), 0.0);
    }

    #[test]
    fn render_golden_counter_gauge() {
        static C: Counter = Counter::new();
        static G: Gauge = Gauge::new();
        C.add(7);
        G.set(0.5);
        let fams = vec![
            Family {
                name: "t_jobs_total",
                help: "test jobs",
                metric: Metric::C(&C),
            },
            Family {
                name: "t_depth",
                help: "test depth",
                metric: Metric::G(&G),
            },
        ];
        assert_eq!(
            render(&fams),
            "# HELP t_jobs_total test jobs\n\
             # TYPE t_jobs_total counter\n\
             t_jobs_total 7\n\
             # HELP t_depth test depth\n\
             # TYPE t_depth gauge\n\
             t_depth 0.5\n"
        );
    }

    #[test]
    fn render_histogram_exposition_shape() {
        static BOUNDS: &[f64] = &[0.5, 2.0];
        static H: Histogram = Histogram::new(BOUNDS);
        H.observe(0.1);
        H.observe(1.0);
        H.observe(9.0);
        let fams = vec![Family {
            name: "t_wait_seconds",
            help: "test wait",
            metric: Metric::H(&H),
        }];
        let text = render(&fams);
        assert!(text.contains("# TYPE t_wait_seconds histogram\n"));
        assert!(text
            .contains("t_wait_seconds_bucket{le=\"0.5\"} 1\n"));
        assert!(text.contains("t_wait_seconds_bucket{le=\"2\"} 2\n"));
        assert!(text
            .contains("t_wait_seconds_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("t_wait_seconds_count 3\n"));
        assert!(text.contains("t_wait_seconds_sum 10.1\n"));
    }

    #[test]
    fn registry_has_at_least_twelve_families_spanning_layers() {
        let fams = families();
        assert!(fams.len() >= 12, "only {} families", fams.len());
        let names: Vec<&str> = fams.iter().map(|f| f.name).collect();
        // one representative per layer
        for want in [
            "omgd_http_requests_total",   // gateway
            "omgd_queue_wait_seconds",    // queue
            "omgd_jobs_completed_total",  // worker
            "omgd_train_step_seconds",    // training
        ] {
            assert!(names.contains(&want), "missing {want}");
        }
        let text = render_prometheus();
        assert_eq!(
            text.matches("# TYPE ").count(),
            fams.len(),
            "every family gets exactly one TYPE line"
        );
    }

    #[test]
    fn summary_json_parses_and_counts() {
        static BOUNDS: &[f64] = &[0.5, 2.0];
        let h = Histogram::new(BOUNDS);
        h.observe(0.25);
        h.observe(1.0);
        let j =
            crate::util::json::Json::parse(&h.summary_json()).unwrap();
        assert_eq!(j.at("count").as_usize(), Some(2));
        assert_eq!(j.at("p50").as_f64(), Some(0.5));
        assert_eq!(j.at("p99").as_f64(), Some(2.0));
    }

    #[test]
    fn journal_ring_wraps_and_keeps_newest() {
        // A private journal (not the global) for deterministic tests.
        let j = Journal {
            inner: Mutex::new(JournalInner {
                buf: Vec::new(),
                write: 0,
                next_id: 0,
            }),
            cap: AtomicUsize::new(3),
            dropped: AtomicU64::new(0),
        };
        for seq in 0..5u64 {
            j.push(Event::new("enqueue", seq));
        }
        assert_eq!(j.pushed(), 5);
        assert_eq!(j.dropped(), 2);
        let tail = j.tail(10);
        assert_eq!(tail.len(), 3);
        // oldest→newest, ids dense
        assert!(tail[0].contains("\"id\":2"));
        assert!(tail[2].contains("\"id\":4"));
        assert!(tail[2].contains("\"seq\":4"));
        // a smaller tail keeps the newest
        let last = j.tail(1);
        assert_eq!(last.len(), 1);
        assert!(last[0].contains("\"id\":4"));
    }

    #[test]
    fn journal_capacity_zero_disables() {
        let j = Journal {
            inner: Mutex::new(JournalInner {
                buf: Vec::new(),
                write: 0,
                next_id: 0,
            }),
            cap: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        };
        j.push(Event::new("run", 1));
        assert_eq!(j.pushed(), 0);
        assert!(j.tail(10).is_empty());
        // re-enable, then shrink with retention
        j.set_capacity(4);
        for seq in 0..4u64 {
            j.push(Event::new("run", seq));
        }
        j.set_capacity(2);
        let tail = j.tail(10);
        assert_eq!(tail.len(), 2);
        assert!(tail[1].contains("\"seq\":3"));
    }

    #[test]
    fn journal_events_render_as_json() {
        let mut ev = Event::new("report", 7);
        ev.hash = "abc".into();
        ev.client = "alpha".into();
        ev.worker = "w-1".into();
        ev.queue_secs = 0.5;
        ev.sync_secs = 0.25;
        ev.run_secs = 1.5;
        ev.secs = 2.25;
        let line = ev.render(3, 1000);
        let j = crate::util::json::Json::parse(&line).unwrap();
        assert_eq!(j.at("kind").as_str(), Some("report"));
        assert_eq!(j.at("seq").as_usize(), Some(7));
        assert_eq!(j.at("worker").as_str(), Some("w-1"));
        assert_eq!(j.at("queue_secs").as_f64(), Some(0.5));
        assert_eq!(j.at("run_secs").as_f64(), Some(1.5));
    }

    #[test]
    fn fault_specs_parse() {
        assert_eq!(
            parse_fault_spec("journal.append"),
            Some(FaultSpec { name: "journal.append".into(), nth: 1 })
        );
        assert_eq!(
            parse_fault_spec(" ckpt.write:3 "),
            Some(FaultSpec { name: "ckpt.write".into(), nth: 3 })
        );
        // malformed specs disable injection instead of erroring
        assert_eq!(parse_fault_spec(""), None);
        assert_eq!(parse_fault_spec("   "), None);
        assert_eq!(parse_fault_spec(":2"), None);
        assert_eq!(parse_fault_spec("x:0"), None);
        assert_eq!(parse_fault_spec("x:abc"), None);
        assert_eq!(parse_fault_spec("x:-1"), None);
    }

    #[test]
    fn faultpoint_is_noop_without_matching_spec() {
        // The test runner never sets OMGD_FAULT (ci.sh only exports it
        // to child `omgd` processes), so any name must be a no-op.
        faultpoint("test.never-armed");
        faultpoint("test.never-armed");
    }

    #[test]
    fn durability_counters_are_registered() {
        let names: Vec<&str> =
            families().iter().map(|f| f.name).collect();
        for want in [
            "omgd_journal_records_total",
            "omgd_journal_replayed_total",
            "omgd_journal_torn_total",
            "omgd_journal_compactions_total",
            "omgd_ckpt_writes_total",
            "omgd_ckpt_resumes_total",
            "omgd_ckpt_parked_total",
        ] {
            assert!(names.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn exec_families_are_registered() {
        let names: Vec<&str> =
            families().iter().map(|f| f.name).collect();
        for want in [
            "omgd_step_threads",
            "omgd_exec_shard_imbalance",
            "omgd_exec_shard_seconds",
        ] {
            assert!(names.contains(&want), "missing {want}");
        }
        // ratio histograms observe raw ratios, not durations
        EXEC_SHARD_IMBALANCE.observe(1.04);
        assert!(EXEC_SHARD_IMBALANCE.count() >= 1);
    }

    #[test]
    fn metrics_levels_parse() {
        assert_eq!(
            "off".parse::<MetricsLevel>().unwrap(),
            MetricsLevel::Off
        );
        assert_eq!(
            "summary".parse::<MetricsLevel>().unwrap(),
            MetricsLevel::Summary
        );
        assert_eq!(
            "full".parse::<MetricsLevel>().unwrap(),
            MetricsLevel::Full
        );
        assert!("loud".parse::<MetricsLevel>().is_err());
        assert_eq!(MetricsLevel::default(), MetricsLevel::Full);
    }
}
