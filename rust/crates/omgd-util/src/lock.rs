//! Shared locking and comparison primitives.
//!
//! Every shared map in the workspace is locked through
//! [`lock_recover`] so a panicking holder (worker threads are
//! panic-isolated by design) can never wedge the process: the poison
//! flag is an advisory we explicitly decline, because all our guarded
//! structures stay structurally valid across panics (inserts/removes
//! are atomic with respect to the guard).
//!
//! [`ct_eq`] is the constant-time byte comparison backing bearer-token
//! auth on the gateway; see `docs/serve-protocol.md`.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Used for every cross-thread map in the workspace (routes, leases,
/// client ledger, artifact index, in-flight tables). A poisoned mutex
/// only indicates that *some* holder panicked — our guarded values are
/// kept consistent under the guard, so continuing is safe and keeps
/// the gateway serving through worker panics.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Constant-time equality over byte strings.
///
/// XOR-accumulates over `max(a.len(), b.len())` positions (reading a
/// fixed `0` pad past either end) and folds the length difference into
/// the accumulator, so neither the content nor the length of the
/// expected secret leaks through early exit. Suitable for comparing
/// bearer tokens; not a general cryptographic primitive.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    let n = a.len().max(b.len());
    let mut diff = (a.len() ^ b.len()) as u8;
    for i in 0..n {
        let x = a.get(i).copied().unwrap_or(0);
        let y = b.get(i).copied().unwrap_or(0);
        diff |= x ^ y;
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn lock_recover_survives_poison() {
        let m = std::sync::Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn ct_eq_matches_slice_equality() {
        let cases: &[(&[u8], &[u8], bool)] = &[
            (b"", b"", true),
            (b"a", b"a", true),
            (b"a", b"b", false),
            (b"secret", b"secret", true),
            (b"secret", b"secres", false),
            (b"secret", b"secre", false),
            (b"", b"x", false),
            (b"longer-token-value", b"longer-token-value", true),
        ];
        for (a, b, want) in cases {
            assert_eq!(ct_eq(a, b), *want, "{a:?} vs {b:?}");
        }
    }
}
