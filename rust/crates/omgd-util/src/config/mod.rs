//! Typed run configuration (parsed from the TOML-subset) + presets.
//!
//! One [`RunConfig`] fully describes a training/fine-tuning run: which AOT
//! model artifact to load, which optimizer family, which *method* (the
//! masking/compression strategy under study), the mask hyper-parameters
//! (`r`, `γ`, `K`), the LR schedule, data generation, and bookkeeping.

pub mod toml;

use self::toml::TomlDoc;
use anyhow::{bail, Context, Result};

/// The memory-efficient training method under study. Mirrors §5's method
/// roster: the paper's OMGD instantiations plus every baseline it
/// compares against.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Full-parameter training (upper baseline).
    Full,
    /// Tensorwise i.i.d. mask, resampled every epoch (§5.2 naïve).
    IidMask,
    /// Tensorwise without-replacement mask — OMGD (§5.2, SGDM-wor).
    WorMask,
    /// LISA: i.i.d. layerwise sampling (Pan et al., 2024), Algorithm 2
    /// without the red lines.
    Lisa,
    /// LISA + gradient scaling only (ablation "LISA-scale").
    LisaScale,
    /// LISA + WOR layer traversal, no scaling (ablation).
    LisaWorNoScale,
    /// LISA-WOR: the paper's full method (WOR traversal + N_L/γ scaling).
    LisaWor,
    /// GaLore-style low-rank projection (top-r subspace via power iter).
    Galore,
    /// GoLore-style low-rank random projection (uniform Stiefel factor).
    Golore,
    /// SIFT-style top-k magnitude gradient masking.
    Sift,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "full" => Method::Full,
            "iid-mask" => Method::IidMask,
            "wor-mask" => Method::WorMask,
            "lisa" => Method::Lisa,
            "lisa-scale" => Method::LisaScale,
            "lisa-wor-no-scale" => Method::LisaWorNoScale,
            "lisa-wor" => Method::LisaWor,
            "galore" => Method::Galore,
            "golore" => Method::Golore,
            "sift" => Method::Sift,
            _ => bail!("unknown method {s:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Full => "full",
            Method::IidMask => "iid-mask",
            Method::WorMask => "wor-mask",
            Method::Lisa => "lisa",
            Method::LisaScale => "lisa-scale",
            Method::LisaWorNoScale => "lisa-wor-no-scale",
            Method::LisaWor => "lisa-wor",
            Method::Galore => "galore",
            Method::Golore => "golore",
            Method::Sift => "sift",
        }
    }

    /// Does this method use the WOR (without-replacement) traversal that
    /// defines OMGD?
    pub fn is_wor(&self) -> bool {
        matches!(
            self,
            Method::WorMask | Method::LisaWor | Method::LisaWorNoScale
        )
    }

    pub fn all() -> &'static [Method] {
        &[
            Method::Full,
            Method::IidMask,
            Method::WorMask,
            Method::Lisa,
            Method::LisaScale,
            Method::LisaWorNoScale,
            Method::LisaWor,
            Method::Galore,
            Method::Golore,
            Method::Sift,
        ]
    }
}

/// Optimizer family (the paper integrates OMGD into both).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptFamily {
    AdamW,
    Sgdm,
}

impl OptFamily {
    pub fn parse(s: &str) -> Result<OptFamily> {
        Ok(match s {
            "adamw" => OptFamily::AdamW,
            "sgdm" | "sgd" => OptFamily::Sgdm,
            _ => bail!("unknown optimizer {s:?}"),
        })
    }
    pub fn name(&self) -> &'static str {
        match self {
            OptFamily::AdamW => "adamw",
            OptFamily::Sgdm => "sgdm",
        }
    }
}

/// Learning-rate schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    Constant,
    /// Multiply by `gamma` at each milestone step.
    MultiStep { milestones: Vec<usize>, gamma: f64 },
    /// Linear warmup to peak then cosine decay to `min_lr`.
    CosineWarmup { warmup: usize, total: usize, min_lr: f64 },
    /// Diminishing `η_t = c0 / max(t, 1)` (§5.1 / Theorem A.1 regime).
    InvT { c0: f64 },
}

impl Schedule {
    /// LR multiplier/value at step `t` given the configured base LR.
    pub fn lr_at(&self, base: f64, t: usize) -> f64 {
        match self {
            Schedule::Constant => base,
            Schedule::MultiStep { milestones, gamma } => {
                let k = milestones.iter().filter(|&&m| t >= m).count();
                base * gamma.powi(k as i32)
            }
            Schedule::CosineWarmup { warmup, total, min_lr } => {
                if t < *warmup {
                    base * (t + 1) as f64 / (*warmup).max(1) as f64
                } else {
                    let progress = (t - warmup) as f64
                        / ((total.saturating_sub(*warmup)).max(1)) as f64;
                    let progress = progress.min(1.0);
                    min_lr
                        + 0.5
                            * (base - min_lr)
                            * (1.0 + (std::f64::consts::PI * progress).cos())
                }
            }
            Schedule::InvT { c0 } => c0 / (t.max(1) as f64),
        }
    }
}

/// Mask / method hyper-parameters (paper notation).
#[derive(Clone, Debug)]
pub struct MaskConfig {
    /// Keep ratio `r` — the fraction of coordinates updated per step.
    pub keep_ratio: f64,
    /// LISA: number of middle layers sampled per period (γ).
    pub gamma: usize,
    /// LISA: sampling period in *epochs or steps* (K); the trainer decides
    /// the unit based on the workload.
    pub period: usize,
    /// GaLore/GoLore rank.
    pub rank: usize,
    /// SIFT top-k fraction.
    pub topk: f64,
}

impl Default for MaskConfig {
    fn default() -> Self {
        Self { keep_ratio: 0.5, gamma: 2, period: 5, rank: 8, topk: 0.1 }
    }
}

/// Optimizer hyper-parameters.
#[derive(Clone, Debug)]
pub struct OptConfig {
    pub family: OptFamily,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    pub momentum: f64,
    pub nesterov: bool,
}

impl Default for OptConfig {
    fn default() -> Self {
        Self {
            family: OptFamily::AdamW,
            lr: 1e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            momentum: 0.9,
            nesterov: true,
        }
    }
}

/// Complete description of one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// AOT config name (`gpt-tiny`, `mlp-glue`, ...).
    pub model: String,
    /// Directory holding `*.hlo.txt` + manifests.
    pub artifacts_dir: String,
    pub method: Method,
    pub opt: OptConfig,
    pub mask: MaskConfig,
    pub schedule: Schedule,
    pub steps: usize,
    pub eval_every: usize,
    pub seed: u64,
    /// Dataset size (N distinct samples for the reshuffling sampler).
    pub dataset_size: usize,
    /// Dataset generator seed (kept distinct from `seed` so method
    /// comparisons share data).
    pub data_seed: u64,
    /// Output directory for metric CSVs.
    pub out_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            model: "mlp-glue".into(),
            artifacts_dir: "artifacts".into(),
            method: Method::Full,
            opt: OptConfig::default(),
            mask: MaskConfig::default(),
            schedule: Schedule::Constant,
            steps: 200,
            eval_every: 50,
            seed: 0,
            dataset_size: 512,
            data_seed: 1234,
            out_dir: "results".into(),
        }
    }
}

impl RunConfig {
    /// Parse from TOML text; unknown keys are ignored, missing keys take
    /// defaults (recorded above).
    pub fn from_toml(src: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(src).context("parsing run config")?;
        Self::from_doc(&doc)
    }

    pub fn from_doc(doc: &TomlDoc) -> Result<RunConfig> {
        let d = RunConfig::default();
        let schedule = match doc.str_or("schedule.kind", "constant") {
            "constant" => Schedule::Constant,
            "multistep" => {
                let milestones = match doc.get("schedule.milestones") {
                    Some(toml::TomlValue::Arr(v)) => v
                        .iter()
                        .filter_map(|x| x.as_i64())
                        .map(|x| x as usize)
                        .collect(),
                    _ => vec![],
                };
                Schedule::MultiStep {
                    milestones,
                    gamma: doc.f64_or("schedule.gamma", 0.1),
                }
            }
            "cosine" => Schedule::CosineWarmup {
                warmup: doc.i64_or("schedule.warmup", 100) as usize,
                total: doc.i64_or(
                    "schedule.total",
                    doc.i64_or("train.steps", d.steps as i64),
                ) as usize,
                min_lr: doc.f64_or("schedule.min_lr", 0.0),
            },
            "inv_t" => Schedule::InvT { c0: doc.f64_or("schedule.c0", 1.0) },
            other => bail!("unknown schedule {other:?}"),
        };
        Ok(RunConfig {
            model: doc.str_or("model", &d.model).to_string(),
            artifacts_dir: doc
                .str_or("artifacts_dir", &d.artifacts_dir)
                .to_string(),
            method: Method::parse(doc.str_or("method", "full"))?,
            opt: OptConfig {
                family: OptFamily::parse(doc.str_or("opt.family", "adamw"))?,
                lr: doc.f64_or("opt.lr", d.opt.lr),
                beta1: doc.f64_or("opt.beta1", d.opt.beta1),
                beta2: doc.f64_or("opt.beta2", d.opt.beta2),
                eps: doc.f64_or("opt.eps", d.opt.eps),
                weight_decay: doc.f64_or("opt.weight_decay",
                                          d.opt.weight_decay),
                momentum: doc.f64_or("opt.momentum", d.opt.momentum),
                nesterov: doc.bool_or("opt.nesterov", d.opt.nesterov),
            },
            mask: MaskConfig {
                keep_ratio: doc.f64_or("mask.keep_ratio",
                                        d.mask.keep_ratio),
                gamma: doc.i64_or("mask.gamma", d.mask.gamma as i64)
                    as usize,
                period: doc.i64_or("mask.period", d.mask.period as i64)
                    as usize,
                rank: doc.i64_or("mask.rank", d.mask.rank as i64) as usize,
                topk: doc.f64_or("mask.topk", d.mask.topk),
            },
            schedule,
            steps: doc.i64_or("train.steps", d.steps as i64) as usize,
            eval_every: doc.i64_or("train.eval_every",
                                    d.eval_every as i64) as usize,
            seed: doc.i64_or("train.seed", d.seed as i64) as u64,
            dataset_size: doc.i64_or("data.size", d.dataset_size as i64)
                as usize,
            data_seed: doc.i64_or("data.seed", d.data_seed as i64) as u64,
            out_dir: doc.str_or("out_dir", &d.out_dir).to_string(),
        })
    }

    /// Validate cross-field invariants before a run starts.
    pub fn validate(&self) -> Result<()> {
        if !(0.0 < self.mask.keep_ratio && self.mask.keep_ratio <= 1.0) {
            bail!("mask.keep_ratio must be in (0,1], got {}",
                  self.mask.keep_ratio);
        }
        if self.mask.gamma == 0 {
            bail!("mask.gamma must be >= 1");
        }
        if self.mask.period == 0 {
            bail!("mask.period must be >= 1");
        }
        if self.steps == 0 {
            bail!("train.steps must be >= 1");
        }
        if self.opt.lr <= 0.0 {
            bail!("opt.lr must be positive");
        }
        if self.dataset_size == 0 {
            bail!("data.size must be >= 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_round_trip() {
        let cfg = RunConfig::from_toml("").unwrap();
        assert_eq!(cfg.method, Method::Full);
        assert_eq!(cfg.opt.family, OptFamily::AdamW);
        cfg.validate().unwrap();
    }

    #[test]
    fn full_config_parses() {
        let cfg = RunConfig::from_toml(
            r#"
model = "gpt-tiny"
method = "lisa-wor"
out_dir = "results/x"

[opt]
family = "sgdm"
lr = 0.1
momentum = 0.95
nesterov = false

[mask]
keep_ratio = 0.25
gamma = 3
period = 10

[schedule]
kind = "multistep"
milestones = [100, 150]
gamma = 0.2

[train]
steps = 500
seed = 7

[data]
size = 2048
"#,
        )
        .unwrap();
        assert_eq!(cfg.model, "gpt-tiny");
        assert_eq!(cfg.method, Method::LisaWor);
        assert_eq!(cfg.opt.family, OptFamily::Sgdm);
        assert_eq!(cfg.opt.momentum, 0.95);
        assert!(!cfg.opt.nesterov);
        assert_eq!(cfg.mask.gamma, 3);
        assert_eq!(cfg.steps, 500);
        assert_eq!(cfg.dataset_size, 2048);
        match cfg.schedule {
            Schedule::MultiStep { ref milestones, gamma } => {
                assert_eq!(milestones, &[100, 150]);
                assert_eq!(gamma, 0.2);
            }
            _ => panic!("wrong schedule"),
        }
        cfg.validate().unwrap();
    }

    #[test]
    fn method_parse_all_names() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.name()).unwrap(), *m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn wor_flag() {
        assert!(Method::WorMask.is_wor());
        assert!(Method::LisaWor.is_wor());
        assert!(!Method::Lisa.is_wor());
        assert!(!Method::Full.is_wor());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut cfg = RunConfig::default();
        cfg.mask.keep_ratio = 0.0;
        assert!(cfg.validate().is_err());
        cfg.mask.keep_ratio = 0.5;
        cfg.steps = 0;
        assert!(cfg.validate().is_err());
        cfg.steps = 1;
        cfg.opt.lr = -1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn schedules() {
        let ms = Schedule::MultiStep { milestones: vec![10, 20], gamma: 0.1 };
        assert_eq!(ms.lr_at(1.0, 5), 1.0);
        assert!((ms.lr_at(1.0, 15) - 0.1).abs() < 1e-12);
        assert!((ms.lr_at(1.0, 25) - 0.01).abs() < 1e-12);

        let cos = Schedule::CosineWarmup { warmup: 10, total: 110,
                                           min_lr: 0.1 };
        assert!(cos.lr_at(1.0, 0) < 0.2); // warming up
        assert!((cos.lr_at(1.0, 9) - 1.0).abs() < 1e-9);
        assert!((cos.lr_at(1.0, 110) - 0.1).abs() < 1e-9);
        assert!((cos.lr_at(1.0, 10_000) - 0.1).abs() < 1e-9); // clamped

        let inv = Schedule::InvT { c0: 2.0 };
        assert_eq!(inv.lr_at(123.0, 4), 0.5);
        assert_eq!(inv.lr_at(123.0, 0), 2.0); // t clamped to 1
    }
}
