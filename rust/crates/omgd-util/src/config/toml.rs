//! Minimal TOML-subset parser (replaces `toml` + `serde`).
//!
//! Supported: `[table]` / `[table.sub]` headers, `key = value` with
//! strings, integers, floats, booleans, and homogeneous inline arrays,
//! plus `#` comments. This covers every config file the launcher accepts;
//! unsupported TOML (multiline strings, dates, array-of-tables) is a
//! parse error, not silent misbehaviour.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path key → value
/// (`[train]` + `lr = 0.1` ⇒ `"train.lr"`).
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| TomlError {
                    line: ln + 1,
                    msg: "unterminated table header".into(),
                })?;
                let name = name.trim();
                if name.is_empty()
                    || !name.chars().all(|c| {
                        c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.')
                    })
                {
                    return Err(TomlError {
                        line: ln + 1,
                        msg: format!("bad table name {name:?}"),
                    });
                }
                prefix = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| TomlError {
                line: ln + 1,
                msg: "expected key = value".into(),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(TomlError { line: ln + 1, msg: "empty key".into() });
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|msg| {
                TomlError { line: ln + 1, msg }
            })?;
            let full = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            doc.entries.insert(full, val);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// All keys under a dotted prefix (for e.g. enumerating `[tasks.*]`).
    pub fn keys_under(&self, prefix: &str) -> Vec<&str> {
        let pfx = format!("{prefix}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&pfx))
            .map(|k| k.as_str())
            .collect()
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("unsupported embedded quote".into());
        }
        return Ok(TomlValue::Str(
            inner.replace("\\n", "\n").replace("\\t", "\t"),
        ));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items: Result<Vec<_>, _> =
            split_top(inner).iter().map(|p| parse_value(p.trim())).collect();
        return Ok(TomlValue::Arr(items?));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        // distinguish ints from floats like "1e3"
        if !s.contains('.') && !s.contains('e') && !s.contains('E') {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

/// Split on commas not nested in brackets/quotes.
fn split_top(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let (mut depth, mut in_str, mut start) = (0usize, false, 0usize);
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let doc = TomlDoc::parse(
            "a = 1\nb = 2.5\nc = \"hi\"\nd = true\n",
        )
        .unwrap();
        assert_eq!(doc.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(doc.get("b").unwrap().as_f64(), Some(2.5));
        assert_eq!(doc.get("c").unwrap().as_str(), Some("hi"));
        assert_eq!(doc.get("d").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn tables_become_dotted_keys() {
        let doc = TomlDoc::parse(
            "[train]\nlr = 0.1\n[train.mask]\nkeep = 0.5\n",
        )
        .unwrap();
        assert_eq!(doc.f64_or("train.lr", 0.0), 0.1);
        assert_eq!(doc.f64_or("train.mask.keep", 0.0), 0.5);
    }

    #[test]
    fn comments_and_blank_lines() {
        let doc = TomlDoc::parse(
            "# header\na = 1 # trailing\n\nb = \"x # not comment\"\n",
        )
        .unwrap();
        assert_eq!(doc.i64_or("a", 0), 1);
        assert_eq!(doc.str_or("b", ""), "x # not comment");
    }

    #[test]
    fn arrays() {
        let doc =
            TomlDoc::parse("xs = [1, 2, 3]\nys = [\"a\", \"b\"]\nzs = []\n")
                .unwrap();
        let xs = match doc.get("xs").unwrap() {
            TomlValue::Arr(v) => v.clone(),
            _ => panic!(),
        };
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_i64(), Some(3));
        assert_eq!(
            doc.get("ys").unwrap(),
            &TomlValue::Arr(vec![
                TomlValue::Str("a".into()),
                TomlValue::Str("b".into())
            ])
        );
        assert_eq!(doc.get("zs").unwrap(), &TomlValue::Arr(vec![]));
    }

    #[test]
    fn int_vs_float() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0\nc = 1e3\n").unwrap();
        assert!(matches!(doc.get("a").unwrap(), TomlValue::Int(3)));
        assert!(matches!(doc.get("b").unwrap(), TomlValue::Float(_)));
        assert_eq!(doc.get("c").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn errors_have_line_numbers() {
        let err = TomlDoc::parse("good = 1\nbad line\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(TomlDoc::parse("[unterminated\n").is_err());
        assert!(TomlDoc::parse("k = @nope\n").is_err());
    }

    #[test]
    fn defaults() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.i64_or("missing", 7), 7);
        assert_eq!(doc.str_or("missing", "d"), "d");
        assert!(doc.bool_or("missing", true));
    }

    #[test]
    fn keys_under_prefix() {
        let doc =
            TomlDoc::parse("[a.x]\nk = 1\n[a.y]\nk = 2\n[b]\nk = 3\n")
                .unwrap();
        let ks = doc.keys_under("a");
        assert_eq!(ks, vec!["a.x.k", "a.y.k"]);
    }
}
