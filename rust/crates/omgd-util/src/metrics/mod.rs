//! Metric sinks (CSV / JSONL), timers and summary statistics.
//!
//! Every experiment writes its series through these sinks so the bench
//! harness and the paper-figure regenerators share one on-disk format:
//! CSV with a header row, one row per logged step.

use anyhow::{ensure, Context, Result};
use std::fs::{self, File};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

/// Append-oriented CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        let file = File::create(&path)
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        let mut out = BufWriter::new(file);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self { out, cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        ensure!(
            values.len() == self.cols,
            "csv row width mismatch: got {} values for {} columns",
            values.len(),
            self.cols
        );
        let line = values
            .iter()
            .map(|v| format_g(*v))
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    /// Mixed string/number row (first column often a label).
    pub fn row_mixed(&mut self, values: &[CsvCell]) -> Result<()> {
        ensure!(
            values.len() == self.cols,
            "csv row width mismatch: got {} values for {} columns",
            values.len(),
            self.cols
        );
        let line = values
            .iter()
            .map(|v| match v {
                CsvCell::S(s) => s.clone(),
                CsvCell::F(x) => format_g(*x),
                CsvCell::I(i) => i.to_string(),
            })
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.out, "{line}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }

    /// Flush and fsync, surfacing errors the implicit `Drop` path would
    /// swallow. Call this at the end of a writer's life when losing the
    /// final rows matters (pool workers writing per-job CSVs).
    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        self.out.get_ref().sync_all()?;
        Ok(())
    }
}

/// Best-effort flush so a short-lived writer that is dropped without an
/// explicit `flush()`/`finish()` never truncates its tail rows. Errors
/// here are unreportable; use [`CsvWriter::finish`] to observe them.
impl Drop for CsvWriter {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

/// Cell for mixed-type CSV rows.
pub enum CsvCell {
    S(String),
    F(f64),
    I(i64),
}

/// Compact float formatting (`%g`-ish): trims trailing zeros, keeps
/// enough digits to round-trip typical metric magnitudes.
pub fn format_g(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else if x.abs() >= 1e-4 && x.abs() < 1e6 {
        let s = format!("{x:.6}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else {
        format!("{x:e}")
    }
}

/// JSONL event log (one JSON object per line, flat string→number/string).
pub struct JsonlWriter {
    out: BufWriter<File>,
}

impl JsonlWriter {
    pub fn create<P: AsRef<Path>>(path: P) -> Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            fs::create_dir_all(dir)?;
        }
        Ok(Self { out: BufWriter::new(File::create(path)?) })
    }

    pub fn event(&mut self, fields: &[(&str, CsvCell)]) -> Result<()> {
        let body = fields
            .iter()
            .map(|(k, v)| match v {
                CsvCell::S(s) => format!("\"{k}\":\"{}\"", escape(s)),
                CsvCell::F(x) => format!("\"{k}\":{}", format_g(*x)),
                CsvCell::I(i) => format!("\"{k}\":{i}"),
            })
            .collect::<Vec<_>>()
            .join(",");
        writeln!(self.out, "{{{body}}}")?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }

    /// Flush and fsync, surfacing errors the implicit `Drop` path would
    /// swallow.
    pub fn finish(mut self) -> Result<()> {
        self.out.flush()?;
        self.out.get_ref().sync_all()?;
        Ok(())
    }
}

/// Best-effort flush on drop (see [`CsvWriter`]'s `Drop`).
impl Drop for JsonlWriter {
    fn drop(&mut self) {
        let _ = self.out.flush();
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Write a residency series — `(step, keep_ratio, state_bytes)`
/// samples the trainer collects at period boundaries from the mask's
/// segment-run view (see `TrainOutcome::residency_series`) — as a CSV
/// with the standard header-row format.
pub fn write_residency_csv<P: AsRef<Path>>(
    path: P,
    series: &[(usize, f64, usize)],
) -> Result<()> {
    let mut w = CsvWriter::create(
        path,
        &["step", "keep_ratio", "state_bytes"],
    )?;
    for &(step, keep, bytes) in series {
        w.row(&[step as f64, keep, bytes as f64])?;
    }
    w.finish()
}

/// Wall-clock timer with named laps.
pub struct Timer {
    start: Instant,
    last: Instant,
}

impl Timer {
    pub fn start() -> Self {
        let now = Instant::now();
        Self { start: now, last: now }
    }

    /// Seconds since construction.
    pub fn total(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Seconds since the previous `lap()` (or construction).
    pub fn lap(&mut self) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        dt
    }
}

/// Streaming summary statistics (Welford) + percentile snapshot support.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self { min: f64::INFINITY, max: f64::NEG_INFINITY, ..Self::default() }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.samples.push(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Exact percentile over recorded samples (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_header_and_rows() {
        let dir = std::env::temp_dir().join("omgd_test_csv");
        let path = dir.join("m.csv");
        {
            let mut w =
                CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&[1.0, 0.5]).unwrap();
            w.row(&[2.0, 0.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n1,0.5\n2,0.25\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_drop_without_flush_keeps_tail_rows() {
        let dir = std::env::temp_dir().join("omgd_test_csv_drop");
        let path = dir.join("d.csv");
        {
            let mut w =
                CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&[1.0, 0.5]).unwrap();
            w.row(&[2.0, 0.25]).unwrap();
            // Dropped without flush(): the Drop impl must flush.
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n1,0.5\n2,0.25\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_finish_flushes_and_syncs() {
        let dir = std::env::temp_dir().join("omgd_test_csv_finish");
        let path = dir.join("f.csv");
        let mut w = CsvWriter::create(&path, &["a"]).unwrap();
        w.row(&[7.0]).unwrap();
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a\n7\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_drop_without_flush_keeps_tail_rows() {
        let dir = std::env::temp_dir().join("omgd_test_jsonl_drop");
        let path = dir.join("d.jsonl");
        {
            let mut w = JsonlWriter::create(&path).unwrap();
            w.event(&[("n", CsvCell::I(1))]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "{\"n\":1}\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_row_width_is_an_error_not_a_panic() {
        // A malformed series must surface as a Result a worker thread
        // can report, never a panic that kills it mid-job.
        let dir = std::env::temp_dir().join("omgd_test_csv2");
        let mut w =
            CsvWriter::create(dir.join("m.csv"), &["a", "b"]).unwrap();
        let err = w.row(&[1.0]).unwrap_err();
        assert!(err.to_string().contains("width mismatch"), "{err}");
        let err = w
            .row_mixed(&[CsvCell::S("x".into())])
            .unwrap_err();
        assert!(err.to_string().contains("width mismatch"), "{err}");
        // the writer stays usable after a rejected row
        w.row(&[1.0, 2.0]).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_round_trips_through_parser() {
        let dir = std::env::temp_dir().join("omgd_test_jsonl");
        let path = dir.join("e.jsonl");
        {
            let mut w = JsonlWriter::create(&path).unwrap();
            w.event(&[
                ("kind", CsvCell::S("step".into())),
                ("loss", CsvCell::F(1.25)),
                ("n", CsvCell::I(3)),
            ])
            .unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(parsed.at("kind").as_str(), Some("step"));
        assert_eq!(parsed.at("loss").as_f64(), Some(1.25));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn residency_csv_round_trips() {
        let dir = std::env::temp_dir().join("omgd_test_residency");
        let path = dir.join("r.csv");
        write_residency_csv(
            &path,
            &[(0, 1.0, 160), (10, 0.25, 40), (20, 0.25, 40)],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "step,keep_ratio,state_bytes\n0,1,160\n10,0.25,40\n\
             20,0.25,40\n"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn format_g_cases() {
        assert_eq!(format_g(1.0), "1");
        assert_eq!(format_g(0.5), "0.5");
        assert_eq!(format_g(0.000001), "1e-6");
        assert_eq!(format_g(123456.75), "123456.75");
    }

    #[test]
    fn summary_statistics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.n, 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
    }

    #[test]
    fn timer_monotonic() {
        let mut t = Timer::start();
        let a = t.lap();
        let b = t.lap();
        assert!(a >= 0.0 && b >= 0.0);
        assert!(t.total() >= a + b - 1e-6);
    }
}
