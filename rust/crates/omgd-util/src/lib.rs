//! # omgd-util — shared plumbing for the OMGD workspace
//!
//! The leaf crate of the workspace: run configuration ([`config`]),
//! CLI argument parsing ([`cli`]), the artifact manifest ([`manifest`]),
//! metrics/CSV emission ([`metrics`]), structured observability
//! ([`obs`]), bench-table printing ([`bench`]), checkpoint packing
//! ([`checkpoint`]), JSON and misc helpers ([`util`]), and the
//! poison-tolerant locking discipline ([`lock`]) every crate above us
//! shares.
//!
//! Layering contract: this crate depends only on `anyhow`. It must
//! never grow a dependency on another omgd crate or on network code —
//! `omgd-core`, `omgd-jobs`, and `omgd-train` all sit on top of it.

pub mod bench;
pub mod checkpoint;
pub mod cli;
pub mod config;
pub mod lock;
pub mod manifest;
pub mod metrics;
pub mod obs;
pub mod util;

pub use lock::{ct_eq, lock_recover};
