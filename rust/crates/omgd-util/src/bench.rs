//! Bench harness (criterion replacement, `harness = false` benches).
//!
//! Provides timed measurement with warmup + repetitions, summary
//! percentiles, and a uniform way to print the paper-table rows each
//! bench regenerates. Benches write their CSV next to stdout output under
//! `results/`.

use crate::metrics::{format_g, Summary, Timer};

/// Measure a closure: `warmup` unrecorded runs, then `iters` recorded.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize,
                           mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut s = Summary::new();
    for _ in 0..iters.max(1) {
        let t = Timer::start();
        f();
        s.add(t.total());
    }
    BenchResult { name: name.to_string(), secs: s }
}

/// Result of one measurement.
pub struct BenchResult {
    pub name: String,
    pub secs: Summary,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        self.secs.mean()
    }

    pub fn report(&self) {
        println!(
            "bench {:40} mean {:>10}s  p50 {:>10}s  p95 {:>10}s  (n={})",
            self.name,
            format_g(self.secs.mean()),
            format_g(self.secs.percentile(50.0)),
            format_g(self.secs.percentile(95.0)),
            self.secs.n,
        );
    }

    /// Throughput helper: items/sec given items per call.
    pub fn per_sec(&self, items: f64) -> f64 {
        items / self.secs.mean()
    }
}

/// Pretty-print a paper-style table to stdout.
pub struct TablePrinter {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row width");
        self.rows.push(cells);
    }

    pub fn row_f(&mut self, label: &str, values: &[f64]) {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.2}")));
        self.row(cells);
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain(std::iter::once(h.len()))
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>()
                                  + 2 * (widths.len() - 1)));
        for r in &self.rows {
            println!("{}", fmt_row(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut calls = 0usize;
        let r = measure("noop", 2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(r.secs.n, 5);
        assert!(r.mean() >= 0.0);
        assert!(r.per_sec(10.0) > 0.0);
    }

    #[test]
    fn table_printer_widths() {
        let mut t = TablePrinter::new(&["method", "acc"]);
        t.row(vec!["full".into(), "92.15".into()]);
        t.row_f("wor", &[91.41]);
        t.print("demo"); // should not panic
        assert_eq!(t.rows.len(), 2);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn table_row_width_checked() {
        let mut t = TablePrinter::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
