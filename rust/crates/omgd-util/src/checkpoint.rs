//! Checkpointing: resumable training state on disk.
//!
//! Binary format (little-endian), version-tagged:
//!
//! ```text
//! magic "OMGDCKPT" | u32 version | u64 step | u64 rng_seed_state
//! u32 n_sections | per section: u32 name_len | name bytes |
//!                                u64 elem_count | f32 data...
//! ```
//!
//! Sections are named flat vectors (`params`, `adam_m`, `adam_v`,
//! `sgdm_buf`, ...) so the format is optimizer-agnostic and
//! forward-compatible: readers ignore unknown sections.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"OMGDCKPT";
const VERSION: u32 = 1;

/// In-memory checkpoint contents.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Checkpoint {
    /// Global step at save time.
    pub step: u64,
    /// Opaque RNG replay tag (callers reseed with it).
    pub rng_state: u64,
    /// Named flat f32 sections.
    pub sections: BTreeMap<String, Vec<f32>>,
}

impl Checkpoint {
    pub fn new(step: u64, rng_state: u64) -> Self {
        Self { step, rng_state, sections: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, data: Vec<f32>) {
        self.sections.insert(name.to_string(), data);
    }

    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.sections.get(name).map(|v| v.as_slice())
    }

    /// Required section or error (resume paths).
    pub fn require(&self, name: &str) -> Result<&[f32]> {
        self.get(name)
            .with_context(|| format!("checkpoint missing section {name:?}"))
    }

    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        // Write via temp + rename so a crash never leaves a torn file.
        let tmp = path.as_ref().with_extension("tmp");
        {
            let mut w = std::io::BufWriter::new(
                std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {tmp:?}"))?,
            );
            w.write_all(MAGIC)?;
            w.write_all(&VERSION.to_le_bytes())?;
            w.write_all(&self.step.to_le_bytes())?;
            w.write_all(&self.rng_state.to_le_bytes())?;
            w.write_all(&(self.sections.len() as u32).to_le_bytes())?;
            for (name, data) in &self.sections {
                w.write_all(&(name.len() as u32).to_le_bytes())?;
                w.write_all(name.as_bytes())?;
                w.write_all(&(data.len() as u64).to_le_bytes())?;
                for x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            w.flush()?;
        }
        std::fs::rename(&tmp, path.as_ref())?;
        Ok(())
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let mut r = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {:?}", path.as_ref()))?,
        );
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not an OMGD checkpoint (bad magic)");
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let step = read_u64(&mut r)?;
        let rng_state = read_u64(&mut r)?;
        let n = read_u32(&mut r)? as usize;
        let mut sections = BTreeMap::new();
        for _ in 0..n {
            let name_len = read_u32(&mut r)? as usize;
            if name_len > 4096 {
                bail!("corrupt checkpoint: section name {name_len} bytes");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name)
                .context("section name not utf8")?;
            let count = read_u64(&mut r)? as usize;
            let mut bytes = vec![0u8; count * 4];
            r.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            sections.insert(name, data);
        }
        Ok(Checkpoint { step, rng_state, sections })
    }
}

/// Pack u64 values losslessly into the f32 section payload: each u64
/// becomes two f32s carrying its low/high 32 bits verbatim. Sections
/// are serialized via `f32::to_le_bytes`, which preserves every bit
/// pattern (including NaNs), so the round trip is exact.
pub fn pack_u64s(xs: &[u64]) -> Vec<f32> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.push(f32::from_bits(x as u32));
        out.push(f32::from_bits((x >> 32) as u32));
    }
    out
}

/// Inverse of [`pack_u64s`]. `None` on an odd-length section (corrupt
/// or mis-tagged).
pub fn unpack_u64s(fs: &[f32]) -> Option<Vec<u64>> {
    if fs.len() % 2 != 0 {
        return None;
    }
    Some(
        fs.chunks_exact(2)
            .map(|c| {
                (c[0].to_bits() as u64)
                    | ((c[1].to_bits() as u64) << 32)
            })
            .collect(),
    )
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("omgd_ckpt_{name}"))
    }

    #[test]
    fn round_trip() {
        let mut c = Checkpoint::new(1234, 0xDEAD_BEEF);
        c.insert("params", vec![1.0, -2.5, 3.25]);
        c.insert("adam_m", vec![0.0; 100]);
        let path = tmp("rt.ckpt");
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, c);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn require_missing_section_errors() {
        let c = Checkpoint::new(0, 0);
        assert!(c.require("params").is_err());
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let mut c = Checkpoint::new(7, 8);
        c.insert("params", vec![1.0; 64]);
        let path = tmp("trunc.ckpt");
        c.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_sections_ok() {
        let c = Checkpoint::new(5, 6);
        let path = tmp("empty.ckpt");
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.step, 5);
        assert_eq!(back.rng_state, 6);
        assert!(back.sections.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn u64_packing_round_trips_through_a_saved_file() {
        // The packed values include NaN-patterned f32s — the on-disk
        // byte path must keep them bit-exact.
        let xs = vec![
            0u64,
            1,
            u64::MAX,
            0x7fc0_0000_7fc0_0000, // both halves are f32 NaNs
            0xdead_beef_cafe_f00d,
        ];
        let mut c = Checkpoint::new(9, 0);
        c.insert("packed", pack_u64s(&xs));
        let path = tmp("packed.ckpt");
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(
            unpack_u64s(back.require("packed").unwrap()),
            Some(xs)
        );
        std::fs::remove_file(&path).ok();
        // odd-length sections are rejected, not mis-decoded
        assert_eq!(unpack_u64s(&[0.0]), None);
        assert_eq!(unpack_u64s(&[]), Some(vec![]));
    }

    #[test]
    fn large_section_round_trip() {
        let mut c = Checkpoint::new(1, 2);
        let data: Vec<f32> = (0..100_000).map(|i| i as f32 * 0.5).collect();
        c.insert("params", data.clone());
        let path = tmp("large.ckpt");
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.get("params").unwrap(), data.as_slice());
        std::fs::remove_file(&path).ok();
    }
}
