//! Table 4 regenerator: tensorwise masks under SGDM (from-scratch image
//! classification substitute).
//!
//! Paper: ResNet-20/18 on CIFAR/ImageNet with r = 0.5 tensorwise masks;
//! SGDM-wor (two-epoch complementary-coverage cycles, eq. 3) beats
//! SGDM-iid, with full-parameter SGDM as ceiling. Here: the `mlp-img`
//! bundle on Gaussian-blob images via the fused masked-SGDM HLO kernel.

use omgd::bench::TablePrinter;
use omgd::config::{OptFamily, RunConfig};
use omgd::data::ClassTask;
use omgd::experiments::*;
use omgd::metrics::{CsvCell, CsvWriter};
use omgd::runtime::Runtime;
use omgd::train::train_classifier;

fn main() -> anyhow::Result<()> {
    if !artifacts_present("mlp-img") {
        eprintln!("mlp-img artifacts missing — run `make artifacts`");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let bundle = load_bundle_sgdm(&rt, "mlp-img")?;
    let epochs = scaled(20, 3);

    // Three datasets of increasing difficulty stand in for
    // CIFAR-10 / CIFAR-100 / ImageNet.
    // Spreads chosen so nearest-mean accuracy lands ~85/70/55% — i.e.
    // real headroom for the optimizer comparison (CIFAR-10 / CIFAR-100 /
    // ImageNet difficulty ordering).
    let datasets = [
        ("IMG-easy", 3.0, 5001u64),
        ("IMG-mid", 4.0, 5002),
        ("IMG-hard", 5.5, 5003),
    ];
    let methods = sgdm_method_roster();
    println!("Table 4: {} datasets × {} methods, {} epochs (SGDM, r=0.5)",
             datasets.len(), methods.len(), epochs);

    let mut table = TablePrinter::new(&[
        "Algorithm", "IMG-easy", "IMG-mid", "IMG-hard",
    ]);
    let csv_path = results_dir().join("table4.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["method", "dataset", "acc"])?;

    for method in &methods {
        let mut cells = vec![format!("SGDM-{}", method.name())];
        for (name, spread, seed) in &datasets {
            let task = ClassTask::gaussian_blobs(
                name,
                bundle.man.data.d_in,
                bundle.man.data.n_class,
                1000,
                400,
                *spread,
                *seed,
            );
            let steps_per_epoch =
                task.n_train().div_ceil(bundle.man.data.batch);
            let mut cfg = RunConfig::default();
            cfg.method = *method;
            cfg.opt.family = OptFamily::Sgdm;
            cfg.opt.lr = 0.05;
            cfg.opt.weight_decay = 1e-4;
            cfg.mask.keep_ratio = 0.5;
            // §5.2: masks switch every epoch; a wor cycle = 2 epochs.
            cfg.mask.period = 1;
            cfg.steps = epochs * steps_per_epoch;
            cfg.eval_every = 0;
            cfg.seed = 42;
            let out = train_classifier(&bundle, &cfg, &task)?;
            cells.push(format!("{:.2}", out.final_metric));
            csv.row_mixed(&[
                CsvCell::S(method.name().into()),
                CsvCell::S((*name).into()),
                CsvCell::F(out.final_metric),
            ])?;
        }
        table.row(cells);
        println!("  finished {}", method.name());
    }
    csv.flush()?;
    table.print(
        "Table 4 — classification accuracy (%), tensorwise masks (SGDM)",
    );
    println!("rows written to {}", csv_path.display());
    Ok(())
}
