//! Figure 5 regenerator: GPT pre-training loss, LISA vs LISA-WOR
//! (+ full-params reference), through the HLO hot path.
//!
//! Paper setting scaled down: GPT-2-124M/OpenWebText → `gpt-tiny` on the
//! synthetic Markov corpus; γ=3 of 6 middle layers (paper: 3 of 12),
//! switching every `period` steps (paper: 100). Expected shape: LISA-WOR's
//! training loss tracks below LISA's.

use omgd::bench::TablePrinter;
use omgd::config::Method;
use omgd::experiments::*;
use omgd::metrics::{CsvCell, CsvWriter};
use omgd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let model = if artifacts_present("gpt-tiny") {
        "gpt-tiny"
    } else {
        "gpt-nano"
    };
    let rt = Runtime::cpu()?;
    let bundle = load_bundle(&rt, model)?;
    let setup = PretrainSetup {
        model: model.into(),
        steps: scaled(120, 40),
        gamma: 3.min(bundle.man.middle_layers().len()),
        period: scaled(20, 5),
        ..PretrainSetup::default()
    };
    println!(
        "Fig.5: pre-training {} for {} steps (γ={}, period={})",
        model, setup.steps, setup.gamma, setup.period
    );

    let csv_path = results_dir().join("fig5_pretrain_loss.csv");
    let mut csv = CsvWriter::create(
        &csv_path, &["method", "step", "loss"],
    )?;
    let mut table = TablePrinter::new(&[
        "method", "final eval loss", "tail train loss", "steps/s",
    ]);

    for method in [Method::Lisa, Method::LisaWor, Method::Full] {
        let out = pretrain_cell(&bundle, method, &setup)?;
        for &(s, l) in &out.loss_series {
            csv.row_mixed(&[
                CsvCell::S(method.name().into()),
                CsvCell::I(s as i64),
                CsvCell::F(l),
            ])?;
        }
        table.row(vec![
            method.name().into(),
            format!("{:.4}", out.final_metric),
            format!("{:.4}", out.tail_loss(20)),
            format!("{:.2}", out.steps_per_sec),
        ]);
        println!("  finished {}", method.name());
    }
    csv.flush()?;
    table.print("Figure 5 — GPT pre-training (LISA vs LISA-WOR)");
    println!("loss curves written to {}", csv_path.display());
    Ok(())
}
