//! §Perf hot-path benchmark: times the three request-path stages —
//! train-step HLO execution, the fused masked-update kernel (L1 Pallas,
//! AOT-compiled), and the native update mirror — plus coordinator
//! overhead (mask refresh). Feeds EXPERIMENTS.md §Perf.

use omgd::bench::{measure, TablePrinter};
use omgd::config::{Method, RunConfig};
use omgd::experiments::*;
use omgd::rng::Rng;
use omgd::runtime::{Runtime, RunsScratch};
use omgd::train::MethodEngine;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let model = if artifacts_present("gpt-tiny") {
        "gpt-tiny"
    } else {
        "gpt-nano"
    };
    let bundle = load_bundle(&rt, model)?;
    let n = bundle.padded_len();
    let corpus = pretrain_corpus(&bundle, 64);
    println!("perf target: {model} (P = {n} params)");

    let mut cfg = RunConfig::default();
    cfg.method = Method::LisaWor;
    cfg.mask.gamma = 2;
    let mut rng = Rng::seed_from_u64(0);
    let mut engine = MethodEngine::new(&bundle.man, &cfg, &mut rng)?;
    engine.on_period(&mut rng)?;

    let mut flat = bundle.init_params()?;
    let idx: Vec<usize> = (0..bundle.man.data.batch).collect();
    let (x, y) = corpus.pack(&idx, bundle.man.data.batch);
    let (_, grad) = bundle.train_step_lm(&flat, &x, &y)?;

    let mut table = TablePrinter::new(&[
        "stage", "mean ms", "p95 ms", "GB/s (state streams)",
    ]);

    // 1. train-step HLO (fwd+bwd).
    let r1 = measure("train_step_hlo", 2, 10, || {
        let _ = bundle.train_step_lm(&flat, &x, &y).unwrap();
    });

    // 2. fused masked-AdamW update via HLO (9 × n × 4 bytes of traffic),
    //    dispatched runs-first like the engine's hot loop.
    let mut m = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let hp = [1e-3f32, 0.9, 0.999, 1e-8, 0.01, 0.1, 0.001, 0.0];
    let desc = engine.runs().descriptors();
    let mut scratch = RunsScratch::new();
    let r2 = measure("masked_adamw_hlo", 2, 20, || {
        bundle
            .adamw_update_runs(&mut flat, &grad, &desc, &mut m, &mut v,
                               &hp, &mut scratch)
            .unwrap();
    });

    // 3. native mirror of the same update (walks the mask's segment
    //    runs: O(active) work, no PJRT dispatch).
    let r3 = measure("masked_adamw_native_runs", 2, 20, || {
        engine.apply_native(&mut flat, &grad, 1e-3);
    });

    // 4. coordinator overhead: period refresh (mask + runs rebuild).
    let r4 = measure("mask_refresh", 5, 50, || {
        engine.on_period(&mut rng).unwrap();
    });

    let bytes = 9.0 * n as f64 * 4.0; // p,g,mask,m,v in + p,m,v out
    for (r, traffic) in [(&r1, None), (&r2, Some(bytes)),
                         (&r3, Some(bytes)), (&r4, None)] {
        table.row(vec![
            r.name.clone(),
            format!("{:.3}", r.mean() * 1e3),
            format!("{:.3}", r.secs.percentile(95.0) * 1e3),
            traffic
                .map(|b| format!("{:.2}", b / r.mean() / 1e9))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    table.print("§Perf — hot-path stage timings");
    println!(
        "\nstep budget: train {:.1} ms + update {:.1} ms; update is {:.1}% \
         of step",
        r1.mean() * 1e3,
        r2.mean() * 1e3,
        100.0 * r2.mean() / (r1.mean() + r2.mean())
    );
    println!(
        "coordinator (mask refresh every K steps) adds {:.3} ms/period — \
         {:.4}% of a step",
        r4.mean() * 1e3,
        100.0 * r4.mean() / r1.mean()
    );
    Ok(())
}
