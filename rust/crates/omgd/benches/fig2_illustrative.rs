//! Figure 2 regenerator: §5.1 error decomposition for the four gradient
//! forms (RR, RR_mask_wor, RR_mask_iid, RR_proj).
//!
//! Prints the convergence-rate table (tail log-log slopes) and writes the
//! four series (overall / decay / data-reshuffle / compression-error) to
//! `results/fig2.csv`. Paper shape to reproduce: RR and RR_mask_wor decay
//! at O(t⁻²); RR_mask_iid and RR_proj flatten to Θ(t⁻¹) with the
//! compression term dominant.

use omgd::bench::TablePrinter;
use omgd::data::LinRegData;
use omgd::experiments::{results_dir, scaled};
use omgd::metrics::{CsvCell, CsvWriter};
use omgd::quadratic::{loglog_slope, run_mean, GradForm, QuadParams};

fn main() -> anyhow::Result<()> {
    let t_max = scaled(1_000_000, 20_000);
    let reps = scaled(5, 2);
    let r = 0.5;
    // Appendix B.1: d=10, n=1000, r=0.5, warm-up 100.
    let data = LinRegData::generate(10, 1000, 2024);
    let params = QuadParams { t_max, ..QuadParams::default() };
    println!(
        "Fig.2 setup: d=10 n=1000 T={t_max} reps={reps} r={r} \
         λmin={:.3} λmax={:.3}",
        data.lambda_min, data.lambda_max
    );

    let forms = [
        GradForm::Rr,
        GradForm::RrMaskWor { r },
        GradForm::RrMaskIid { r },
        GradForm::RrProj { r },
    ];

    let mut table = TablePrinter::new(&[
        "method", "final ‖θ−θ*‖²", "slope", "paper expectation",
    ]);
    let csv_path = results_dir().join("fig2.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["method", "step", "overall", "decay", "reshuffle",
          "compression"],
    )?;

    for form in forms {
        let tr = run_mean(&data, form, params, reps, 1);
        let slope = loglog_slope(&tr.steps, &tr.overall, 0.4);
        let expect = match form {
            GradForm::Rr | GradForm::RrMaskWor { .. } => "O(t^-2)",
            _ => "Ω(t^-1)",
        };
        table.row(vec![
            form.name().into(),
            format!("{:.3e}", tr.overall.last().unwrap()),
            format!("{slope:.2}"),
            expect.into(),
        ]);
        for i in 0..tr.steps.len() {
            csv.row_mixed(&[
                CsvCell::S(form.name().into()),
                CsvCell::I(tr.steps[i] as i64),
                CsvCell::F(tr.overall[i]),
                CsvCell::F(tr.decay[i]),
                CsvCell::F(tr.reshuffle[i]),
                CsvCell::F(tr.compression[i]),
            ])?;
        }
    }
    csv.flush()?;
    table.print("Figure 2 — §5.1 convergence rates");
    println!("series written to {}", csv_path.display());
    Ok(())
}
