//! Table 5 regenerator: layerwise methods on image-classification
//! fine-tuning (ViT-base substitute = `mlp-img` bundle, AdamW).
//!
//! Paper shape: LISA-WOR ≥ LISA ≈ full-params ceiling, with GoLore and
//! SIFT close behind; the γ/K setting follows B.2 (γ=3, K=5 scaled).
//!
//! The sweep is submitted as a job grid (`experiments::table5_grid` →
//! `jobs::run_grid`): cells shard across `OMGD_WORKERS` threads and
//! completed cells replay from the result cache (`OMGD_FORCE=1`
//! recomputes). Emits Fig. 3-style test-loss curves to
//! `results/fig3_test_loss.csv`.

use omgd::bench::TablePrinter;
use omgd::experiments::*;
use omgd::jobs::{default_workers, force_from_env, run_grid, GridOptions};
use omgd::metrics::{CsvCell, CsvWriter};

fn main() -> anyhow::Result<()> {
    if !artifacts_present("mlp-img") {
        eprintln!("mlp-img artifacts missing — run `make artifacts`");
        return Ok(());
    }
    let specs = table5_grid();
    let methods = adamw_method_roster();
    let opts = GridOptions {
        workers: default_workers(),
        force: force_from_env(),
        ..GridOptions::default()
    };
    println!(
        "Table 5: {} grid cells ({} datasets × {} methods, AdamW γ=3 \
         K=5), {} workers",
        specs.len(),
        TABLE5_DATASETS.len(),
        methods.len(),
        opts.workers
    );
    let report = run_grid(specs, &opts)?;
    println!(
        "grid done: {} ok, {} failed, {} from cache ({:.0}% hit)",
        report.n_ok(),
        report.n_failed(),
        report.n_cached(),
        100.0 * report.cache_hit_rate()
    );
    if report.n_failed() > 0 {
        // Bail before any aggregation: a partially-failed grid must not
        // leave NaN-poisoned tables/CSVs on disk.
        report.print_failures();
        anyhow::bail!("{} grid cell(s) failed — no tables written",
                      report.n_failed());
    }

    let acc = report.mean_metric_by(|r| {
        (r.spec.cfg.method.name().to_string(),
         r.spec.kind.dataset().to_string())
    });

    let mut table = TablePrinter::new(&[
        "Algorithm", "IMG-easy", "IMG-mid", "IMG-hard",
    ]);
    let csv_path = results_dir().join("table5.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["method", "dataset", "acc"])?;
    for method in &methods {
        let mut cells = vec![method.name().to_string()];
        for (name, _, _) in TABLE5_DATASETS {
            let key = (method.name().to_string(), name.to_string());
            let a = acc.get(&key).copied().unwrap_or(f64::NAN);
            cells.push(format!("{a:.2}"));
            csv.row_mixed(&[
                CsvCell::S(method.name().into()),
                CsvCell::S(name.into()),
                CsvCell::F(a),
            ])?;
        }
        table.row(cells);
    }
    csv.finish()?;

    // Fig. 3 test-loss curves on the middle-difficulty dataset.
    let mut fig3 = CsvWriter::create(
        results_dir().join("fig3_test_loss.csv"),
        &["method", "step", "test_loss"],
    )?;
    for r in &report.results {
        if r.spec.kind.dataset() == "IMG-mid" {
            if let Some(o) = r.outcome() {
                for &(s, l, _) in &o.eval_series {
                    fig3.row_mixed(&[
                        CsvCell::S(r.spec.cfg.method.name().into()),
                        CsvCell::I(s as i64),
                        CsvCell::F(l),
                    ])?;
                }
            }
        }
    }
    fig3.finish()?;

    table.print("Table 5 — fine-tuning accuracy (%), layerwise methods");
    println!("rows written to {}", csv_path.display());
    println!("test-loss curves (Fig. 3) in results/fig3_test_loss.csv");
    Ok(())
}
