//! Table 6 regenerator: LISA-WOR hyper-parameter ablation on CoLA-like —
//! sampling layers γ ∈ {1,2,3,4,6} × period K ∈ {1,2,3,5,6}.
//!
//! Paper shape: accuracy improves with γ (more unfrozen capacity per
//! period); K has a milder, non-monotone effect with very frequent
//! switching (small K at small γ) slightly hurting.
//!
//! The sweep is submitted as a job grid (`experiments::table6_grid` →
//! `jobs::run_grid`): cells shard across `OMGD_WORKERS` threads and
//! completed cells replay from the result cache (`OMGD_FORCE=1`
//! recomputes).

use omgd::bench::TablePrinter;
use omgd::experiments::*;
use omgd::jobs::{default_workers, force_from_env, run_grid, GridOptions};
use omgd::metrics::{CsvCell, CsvWriter};

fn main() -> anyhow::Result<()> {
    let gammas = [1usize, 2, 3, 4, 6];
    let periods = [1usize, 2, 3, 5, 6];
    let specs = table6_grid();
    let opts = GridOptions {
        workers: default_workers(),
        force: force_from_env(),
        ..GridOptions::default()
    };
    println!(
        "Table 6: γ × K sweep on CoLA-like ({} cells), {} workers",
        specs.len(),
        opts.workers
    );
    let report = run_grid(specs, &opts)?;
    println!(
        "grid done: {} ok, {} failed, {} from cache ({:.0}% hit)",
        report.n_ok(),
        report.n_failed(),
        report.n_cached(),
        100.0 * report.cache_hit_rate()
    );
    if report.n_failed() > 0 {
        // Bail before any aggregation: a partially-failed grid must not
        // leave NaN-poisoned tables/CSVs on disk.
        report.print_failures();
        anyhow::bail!("{} grid cell(s) failed — no tables written",
                      report.n_failed());
    }

    let acc = report
        .mean_metric_by(|r| (r.spec.cfg.mask.gamma, r.spec.cfg.mask.period));

    let mut headers: Vec<String> = vec!["γ \\ K".into()];
    headers.extend(periods.iter().map(|k| format!("K={k}")));
    let headers_ref: Vec<&str> =
        headers.iter().map(|s| s.as_str()).collect();
    let mut table = TablePrinter::new(&headers_ref);

    let csv_path = results_dir().join("table6.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["gamma", "period", "acc"])?;
    for &gamma in &gammas {
        let mut cells = vec![format!("γ={gamma}")];
        for &period in &periods {
            let a = acc.get(&(gamma, period)).copied().unwrap_or(f64::NAN);
            cells.push(format!("{a:.2}"));
            csv.row_mixed(&[
                CsvCell::I(gamma as i64),
                CsvCell::I(period as i64),
                CsvCell::F(a),
            ])?;
        }
        table.row(cells);
    }
    csv.finish()?;
    table.print("Table 6 — LISA-WOR ablation, accuracy (%) on CoLA-like");
    println!("rows written to {}", csv_path.display());
    Ok(())
}
