//! Table 3 regenerator: GLUE-like fine-tuning, 8 tasks × 7 methods.
//!
//! Substitution (DESIGN.md): synthetic planted-teacher tasks stand in for
//! GLUE; the comparison structure (same data, same budget, method-only
//! variation) is preserved. Expected shape: LISA-WOR ≥ {LISA, ablations,
//! GoLore, SIFT} with Full params as the ceiling; the wor+scale combo
//! beats either modification alone on average.
//!
//! The sweep is submitted as a job grid (`experiments::table3_grid` →
//! `jobs::run_grid`): cells shard across `OMGD_WORKERS` threads and
//! completed cells replay from the result cache (`OMGD_FORCE=1`
//! recomputes). Also emits Fig. 4/7-style training-loss curves for CoLA
//! to `results/fig4_cola_loss.csv`.

use omgd::bench::TablePrinter;
use omgd::data::GLUE_LIKE_TASKS;
use omgd::experiments::*;
use omgd::jobs::{default_workers, force_from_env, run_grid, GridOptions};
use omgd::metrics::{CsvCell, CsvWriter};

fn main() -> anyhow::Result<()> {
    // Synthetic tasks carry more per-run noise than real GLUE, so each
    // cell averages over independent training seeds (shared data).
    let seeds: &[u64] = &[0, 1];
    let specs = table3_grid(seeds);
    let methods = adamw_method_roster();
    let opts = GridOptions {
        workers: default_workers(),
        force: force_from_env(),
        ..GridOptions::default()
    };
    println!(
        "Table 3: {} grid cells ({} tasks × {} methods × {} seeds), \
         {} workers",
        specs.len(),
        GLUE_LIKE_TASKS.len(),
        methods.len(),
        seeds.len(),
        opts.workers
    );
    let report = run_grid(specs, &opts)?;
    println!(
        "grid done: {} ok, {} failed, {} from cache ({:.0}% hit)",
        report.n_ok(),
        report.n_failed(),
        report.n_cached(),
        100.0 * report.cache_hit_rate()
    );
    if report.n_failed() > 0 {
        // Bail before any aggregation: a partially-failed grid must not
        // leave NaN-poisoned tables/CSVs on disk.
        report.print_failures();
        anyhow::bail!("{} grid cell(s) failed — no tables written",
                      report.n_failed());
    }

    // Seed-averaged accuracy and tail loss per (method, task).
    let cell_key = |r: &omgd::jobs::JobResult| {
        (r.spec.cfg.method.name().to_string(),
         r.spec.kind.dataset().to_string())
    };
    let acc = report.mean_metric_by(cell_key);
    let tail = report.mean_by(cell_key, |o| o.tail_loss);

    let mut headers: Vec<&str> = vec!["Algorithm"];
    headers.extend(GLUE_LIKE_TASKS.iter().map(|t| t.name));
    headers.push("Avg");
    let mut table = TablePrinter::new(&headers);

    let csv_path = results_dir().join("table3.csv");
    let mut csv = CsvWriter::create(
        &csv_path, &["method", "task", "acc", "tail_loss"],
    )?;
    for method in &methods {
        let mut cells = vec![method.name().to_string()];
        let mut sum = 0.0;
        for spec_t in &GLUE_LIKE_TASKS {
            let key = (method.name().to_string(), spec_t.name.to_string());
            let a = acc.get(&key).copied().unwrap_or(f64::NAN);
            let t = tail.get(&key).copied().unwrap_or(f64::NAN);
            cells.push(format!("{a:.2}"));
            sum += a;
            csv.row_mixed(&[
                CsvCell::S(method.name().into()),
                CsvCell::S(spec_t.name.into()),
                CsvCell::F(a),
                CsvCell::F(t),
            ])?;
        }
        cells.push(format!("{:.2}", sum / GLUE_LIKE_TASKS.len() as f64));
        table.row(cells);
    }
    csv.finish()?;

    // Fig. 4/7 loss curves: CoLA, first seed, every method (results are
    // in submission order, i.e. roster order).
    let mut cola_curves = CsvWriter::create(
        results_dir().join("fig4_cola_loss.csv"),
        &["method", "step", "loss"],
    )?;
    for r in &report.results {
        if r.spec.kind.dataset() == "CoLA" && r.spec.cfg.seed == seeds[0] {
            if let Some(o) = r.outcome() {
                for &(st, l) in &o.loss_series {
                    cola_curves.row_mixed(&[
                        CsvCell::S(r.spec.cfg.method.name().into()),
                        CsvCell::I(st as i64),
                        CsvCell::F(l),
                    ])?;
                }
            }
        }
    }
    cola_curves.finish()?;

    table.print("Table 3 — fine-tuning accuracy (%) on GLUE-like tasks");
    println!("rows written to {}", csv_path.display());
    println!("CoLA loss curves (Fig. 4/7) in results/fig4_cola_loss.csv");
    Ok(())
}
