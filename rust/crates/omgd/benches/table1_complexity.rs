//! Table 1 regenerator: empirical iteration-complexity comparison.
//!
//! On the §5.1 quadratic (a μ-PL / convex objective), the paper's Table 1
//! rates translate to first-passage scaling of T(ε) = min{t : ‖θ_t−θ*‖ ≤ ε}:
//!   with-replacement SGD:   ρ_t = Θ(t⁻¹) ⇒ T(ε) ~ ε⁻²
//!   RR-SGD / OMGD:          ρ_t = O(t⁻²) ⇒ T(ε) ~ ε⁻¹
//!   i.i.d. compressors:     ρ_t = Ω(t⁻¹) ⇒ T(ε) ~ ε⁻²  (GoLore-like)
//!
//! We fit log T against log(1/ε) and print the slope next to the paper's
//! prediction.

use omgd::bench::TablePrinter;
use omgd::data::LinRegData;
use omgd::experiments::scaled;
use omgd::quadratic::{first_passage, GradForm, QuadParams};

fn fit_slope(eps: &[f64], ts: &[Option<usize>]) -> Option<f64> {
    let pts: Vec<(f64, f64)> = eps
        .iter()
        .zip(ts)
        .filter_map(|(&e, t)| t.map(|t| ((1.0 / e).ln(), (t as f64).ln())))
        .collect();
    if pts.len() < 3 {
        return None;
    }
    let n = pts.len() as f64;
    let mx = pts.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pts.iter().map(|p| p.1).sum::<f64>() / n;
    let num: f64 = pts.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum();
    let den: f64 = pts.iter().map(|p| (p.0 - mx) * (p.0 - mx)).sum();
    Some(num / den)
}

fn main() {
    let t_max = scaled(1_000_000, 50_000);
    let data = LinRegData::generate(10, 1000, 31);
    let params = QuadParams {
        t_max,
        points_per_decade: 24,
        ..QuadParams::default()
    };
    // ε grid inside the resolvable range for t_max.
    let eps: Vec<f64> =
        (0..8).map(|i| 0.5 * 0.6f64.powi(i)).collect();
    println!("Table 1 setup: T={t_max}, ε ∈ [{:.4}, {:.2}]",
             eps.last().unwrap(), eps[0]);

    let rows: Vec<(&str, GradForm, &str)> = vec![
        ("SGD (iid sampling)", GradForm::Iid, "ε⁻² (slope 2)"),
        ("RR-SGD", GradForm::Rr, "ε⁻¹ (slope 1)"),
        ("GoLore-like (RR_proj)", GradForm::RrProj { r: 0.5 },
         "ε⁻² (slope 2)"),
        ("LISA-like (RR_mask_iid)", GradForm::RrMaskIid { r: 0.5 },
         "ε⁻² (slope 2)"),
        ("OMGD (RR_mask_wor)", GradForm::RrMaskWor { r: 0.5 },
         "ε⁻¹ (slope 1)"),
    ];

    let mut table = TablePrinter::new(&[
        "algorithm", "T(ε) slope", "paper rate (PL/convex)",
    ]);
    for (name, form, expect) in rows {
        let ts = first_passage(&data, form, params, &eps, 5);
        let slope = fit_slope(&eps, &ts)
            .map(|s| format!("{s:.2}"))
            .unwrap_or_else(|| "n/a".into());
        table.row(vec![name.into(), slope, expect.into()]);
    }
    table.print("Table 1 — empirical iteration-complexity slopes");
    println!("(slope of log T(ε) vs log 1/ε; smaller = better scaling)");
}
