//! Table 8 / Figure 6 regenerator: analytic memory breakdown for
//! pre-training LLaMA-7B (batch 512 setting of §5.4).
//!
//! Paper rows (GB): Full 12.55/12.55/25.10/14.66 → 64.86;
//! GaLore/GoLore 12.55/12.55/1.73/4.40 → 31.23 (−52%);
//! LISA/LISA-wor 12.55/1.24/2.48/3.29 → 19.56 (−70%).

use omgd::bench::TablePrinter;
use omgd::experiments::results_dir;
use omgd::memory::{breakdown, ArchSpec, MemBreakdown, MemPolicy};
use omgd::metrics::{CsvCell, CsvWriter};

fn main() -> anyhow::Result<()> {
    let arch = ArchSpec::llama_7b();
    println!("LLaMA-7B inventory: {:.3}B params, {} tensors",
             arch.total_params() as f64 / 1e9, arch.tensors.len());

    let rows = [
        ("Full params", MemPolicy::Full,
         [12.55, 12.55, 25.10, 14.66, 64.86]),
        ("GaLore/GoLore", MemPolicy::Galore(128),
         [12.55, 12.55, 1.73, 4.40, 31.23]),
        ("LISA/LISA-wor", MemPolicy::Lisa(2),
         [12.55, 1.24, 2.48, 3.29, 19.56]),
    ];

    let mut table = TablePrinter::new(&[
        "Method", "Model", "Gradients", "Optimizer", "Others", "Total",
        "paper Total", "reduction",
    ]);
    let csv_path = results_dir().join("table8.csv");
    let mut csv = CsvWriter::create(
        &csv_path,
        &["method", "model_gb", "grad_gb", "opt_gb", "others_gb",
          "total_gb", "paper_total_gb"],
    )?;

    let full_total =
        breakdown(&arch, MemPolicy::Full).total();
    for (name, policy, paper) in rows {
        let b = breakdown(&arch, policy);
        let total = b.total();
        let red = 100.0 * (1.0 - total as f64 / full_total as f64);
        table.row(vec![
            name.into(),
            format!("{:.2}", MemBreakdown::gb(b.model)),
            format!("{:.2}", MemBreakdown::gb(b.gradients)),
            format!("{:.2}", MemBreakdown::gb(b.optimizer)),
            format!("{:.2}", MemBreakdown::gb(b.others)),
            format!("{:.2}", MemBreakdown::gb(total)),
            format!("{:.2}", paper[4]),
            format!("{red:.0}%"),
        ]);
        csv.row_mixed(&[
            CsvCell::S(name.into()),
            CsvCell::F(MemBreakdown::gb(b.model)),
            CsvCell::F(MemBreakdown::gb(b.gradients)),
            CsvCell::F(MemBreakdown::gb(b.optimizer)),
            CsvCell::F(MemBreakdown::gb(b.others)),
            CsvCell::F(MemBreakdown::gb(total)),
            CsvCell::F(paper[4]),
        ])?;
    }
    csv.flush()?;
    table.print("Table 8 / Fig. 6 — LLaMA-7B memory breakdown (GB)");
    println!("rows written to {}", csv_path.display());

    // Fig. 6 sanity: the 24 GB consumer-GPU line.
    let lisa = breakdown(&arch, MemPolicy::Lisa(2));
    println!(
        "\nLISA-wor total {:.2} GB {} 24 GB (RTX-4090 class) — {}",
        MemBreakdown::gb(lisa.total()),
        if MemBreakdown::gb(lisa.total()) < 24.0 { "<" } else { "≥" },
        if MemBreakdown::gb(lisa.total()) < 24.0 {
            "fits on consumer GPUs, as the paper claims"
        } else {
            "does NOT fit — regression vs paper claim"
        }
    );
    Ok(())
}
