//! `omgd` — launcher CLI for the OMGD reproduction.
//!
//! Subcommands:
//!   info                              runtime + artifact inventory
//!   train      --model gpt-tiny ...   LM pre-training via HLO hot path
//!   finetune   --task CoLA ...        classifier fine-tuning, any method
//!   illustrative ...                  §5.1 quadratic study (Fig. 2 data)
//!   memory     [--arch llama-7b]      analytic memory breakdown (Tab. 8)
//!   grid                              declarative sweep over methods ×
//!                                     seeds × keep-ratios on a sharded
//!                                     worker pool with result caching
//!     --kind finetune|pretrain        workload family (default finetune)
//!     --tasks CoLA,SST-2              finetune: GLUE-like task list
//!     --methods full,lisa,lisa-wor    method roster for the sweep
//!     --seeds 0,1,2                   training seeds per cell
//!     --keep-ratios 0.5               mask keep-ratio axis
//!     --workers N                     worker threads (OMGD_WORKERS env)
//!     --force                         recompute cached cells
//!     --cache-dir DIR                 cache root (target/omgd-cache)
//!     --out results/grid.csv          deterministic per-cell aggregate
//!     --curves results/curves.csv     per-step loss curves per cell
//!     --remote HOST:PORT              submit to a gateway instead of
//!                                     running on the local pool
//!   serve                             long-lived job service: JSONL on
//!                                     stdin/stdout, or — with --listen
//!                                     — an HTTP/1.1 gateway serving N
//!                                     concurrent clients and remote
//!                                     workers from one pool + cache
//!                                     (docs/serve-protocol.md)
//!     --listen 127.0.0.1:8080         bind an HTTP gateway (:0 = any
//!                                     free port, printed to stderr)
//!     --workers N --force --cache-dir DIR
//!     --max-conns N --max-in-flight N --queue-cap N   (HTTP mode only)
//!     --lease-secs N --poll-secs N    remote-worker lease TTL / poll
//!     --client-quota N                per-client in-flight cap
//!     --affinity-window N             artifact-affinity scan bound
//!     --keepalive-idle-secs N         idle keep-alive connection cap
//!     --metrics off|summary|full      telemetry verbosity: /metrics
//!                                     exposition and the /events
//!                                     job-lifecycle journal
//!   stats      --connect HOST:PORT    pretty-print a live gateway's
//!                                     /stats + /metrics (+ --events N
//!                                     journal tail)
//!   worker                            remote worker agent for a
//!                                     gateway: lease → artifact sync →
//!                                     run → report, until drained
//!     --connect HOST:PORT --workers N --id NAME
//!     --cache-dir DIR --artifact-store DIR --force --max-failures N
//!     --max-jobs N --idle-exit SECS   lifecycle bounds for autoscaling
//!     --step-threads N                per-job step-pool width (0=inherit)
//!   cache-gc                          prune the result cache by age
//!                                     and/or total size (true LRU)
//!     --max-age-secs N --max-bytes N [--dry-run] [--cache-dir DIR]
//!   microbench                        segment-run vs dense masked-
//!                                     AdamW step timing (BENCH_*.json)
//!     --n 65536 --keep 0.25 --steps 10000 [--out FILE]
//!
//! `train`/`finetune` also accept `--residency FILE.csv` to export the
//! per-period (step, keep_ratio, state_bytes) series.
//!
//! Every flag has a default; `omgd <cmd> --help` lists them.

use anyhow::{bail, Result};
use omgd::bench::TablePrinter;
use omgd::cli::Args;
use omgd::config::{Method, OptFamily, RunConfig, Schedule};
use omgd::data::{ClassTask, Corpus, CorpusConfig, LinRegData};
use omgd::experiments::{finetune_spec, pretrain_config, FinetuneSetup,
                        PretrainSetup};
use omgd::jobs::{
    gateway_get, run_grid, run_grid_remote_auth, run_worker,
    ExperimentKind, GcPolicy, GridOptions, JobSpec, ListenOptions,
    ResultCache, WorkerOptions,
};
use omgd::memory::{breakdown, ArchSpec, MemBreakdown, MemPolicy};
use omgd::metrics::CsvWriter;
use omgd::quadratic::{loglog_slope, run_mean, GradForm, QuadParams};
use omgd::runtime::bundle::UpdateKind;
use omgd::runtime::{artifacts_dir, ModelBundle, Runtime};
use omgd::train::{train_classifier, train_lm};

fn main() {
    let args = match Args::parse_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    // Global `--threads N`: pin the shard-parallel execution pool width
    // for this process (wins over the OMGD_THREADS env var; unset =
    // available parallelism). Set before any engine spawns its pool.
    if let Some(t) = args.opt_u64("threads")? {
        std::env::set_var("OMGD_THREADS", t.to_string());
    }
    match args.cmd.as_str() {
        "info" => cmd_info(args),
        "check" => cmd_check(args),
        "train" => cmd_train(args),
        "finetune" => cmd_finetune(args),
        "illustrative" => cmd_illustrative(args),
        "memory" => cmd_memory(args),
        "grid" => cmd_grid(args),
        "serve" => cmd_serve(args),
        "stats" => cmd_stats(args),
        "worker" => cmd_worker(args),
        "cache-gc" => cmd_cache_gc(args),
        "microbench" => cmd_microbench(args),
        "" | "help" | "--help" => {
            print!("{}", USAGE);
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

const USAGE: &str = "\
omgd — Omni-Masked Gradient Descent reproduction

USAGE: omgd <subcommand> [flags]

  global: --threads N                shard-parallel step-pool width for
                                     this process (OMGD_THREADS env;
                                     unset = available parallelism)

  info                               platform + artifact inventory
  check        self-test every artifact: HLO update kernel vs native
               mirror cross-check + one train-step execution
  train        LM pre-training (HLO hot path)
    --model gpt-tiny --method lisa-wor --steps 200 --lr 6e-4
    --gamma 3 --period 100 --seed 0 --out results/pretrain.csv
  finetune     classifier fine-tuning on a synthetic GLUE-like task
    --task CoLA --method lisa-wor --epochs 30 --gamma 4 --period 1
  illustrative §5.1 quadratic (writes Fig. 2 series)
    --t-max 100000 --reps 5 --r 0.5 --out results/fig2.csv
  memory       analytic memory breakdown (Table 8 / Fig. 6)
    --arch llama-7b --rank 128 --gamma 2
  grid         sweep methods × seeds × keep-ratios on a worker pool
               (cells cached under target/omgd-cache by config hash);
               with --remote, submit the grid to a gateway instead of
               running locally (aggregates are byte-identical)
    --kind finetune --tasks CoLA --methods full,lisa,lisa-wor
    --seeds 0,1,2 --keep-ratios 0.5 --epochs 4 --workers 4
    [--force] [--cache-dir DIR] [--out results/grid.csv]
    [--remote HOST:PORT] [--client TOKEN] [--token BEARER]
  serve        long-lived job service sharing one worker pool + cache
               stdin mode: JSONL requests in, JSONL results out
               ({\"cmd\":\"shutdown\"} or EOF ends)
               HTTP mode (--listen): POST /jobs streams NDJSON results;
               GET /healthz /stats /metrics /events /cache; POST
               /work/lease hands jobs to remote `omgd worker` agents
               (--workers 0 = pure coordinator); POST /shutdown drains
               (protocol: docs/serve-protocol.md); a crash-safe job
               journal under the cache dir is replayed on restart so
               queued/completed jobs survive crashes
               (docs/durability.md)
    --workers 4 [--force] [--cache-dir DIR]
    [--cache-max-age-secs N] [--cache-max-bytes N]
    HTTP mode only: [--listen 127.0.0.1:8080] [--max-conns 64]
    [--max-in-flight 32] [--queue-cap N] [--lease-secs 60]
    [--poll-secs 20] [--client-quota N] [--affinity-window 16]
    [--keepalive-idle-secs 60] [--metrics off|summary|full]
    [--auth-token BEARER] (or OMGD_AUTH_TOKEN env): require
    `Authorization: Bearer` on /jobs /work/* /artifacts/* /shutdown;
    probes (/healthz /stats /metrics /events /cache) stay open
  stats        pretty-print a live gateway's /stats counters, phase
               latency percentiles, and /metrics family count; with
               --events N, tail the job-lifecycle event journal
               (docs/observability.md)
    --connect HOST:PORT [--events N] [--timeout-secs 10]
  worker       remote worker agent: long-poll a gateway for leased
               jobs, sync missing artifacts by fingerprint, run on a
               local pool, report results; exits when the gateway
               drains (see docs/operations.md)
    --connect HOST:PORT [--workers N] [--id NAME] [--cache-dir DIR]
    [--artifact-store DIR] [--force] [--max-failures 5]
    [--max-jobs N] [--idle-exit SECS] [--ckpt-period STEPS]
    [--step-threads N] (per-job shard-parallel pool width; 0 = inherit)
    [--token BEARER] (for gateways running --auth-token)
  cache-gc     prune the result cache (age cap, then size cap evicting
               least-recently-used-first; cache hits refresh recency);
               parked train checkpoints answer only to the age cap and
               never while their job is live in the journal;
               see docs/operations.md
    --max-age-secs N --max-bytes N [--dry-run] [--cache-dir DIR]
  microbench   time native masked-AdamW steps on the segment-run path
               vs the dense reference and print the ratio (no
               artifacts needed; steps scale with OMGD_BENCH_SCALE);
               also sweeps the shard-parallel step over {1,2,4}
               threads x keep {0.05,0.25}, each arm bitwise-verified
               against the serial walk before its timing counts;
               the BENCH json row is stamped with git rev, bench
               scale, worker count, and a unix timestamp so CI can
               track the perf trajectory across revisions
    --n 65536 --keep 0.25 --steps 10000 [--sweep-steps 1000]
    [--out BENCH_maskruns.json]
";

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args.get("artifacts"));
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    println!("artifacts dir: {}", dir.display());
    if dir.exists() {
        let mut names: Vec<String> = std::fs::read_dir(&dir)?
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let n = e.file_name().to_string_lossy().into_owned();
                n.strip_suffix(".json").map(|s| s.to_string())
            })
            .collect();
        names.sort();
        for n in names {
            if n == "linreg" {
                println!("  config linreg (d=10 gradient artifact)");
                continue;
            }
            if let Ok(man) =
                omgd::manifest::Manifest::load(&dir, &n)
            {
                println!(
                    "  config {:10} kind={:4} params={:>9} padded={:>9} \
                     middle_layers={}",
                    man.name, man.kind, man.total_len, man.padded_len,
                    man.middle_layers().len()
                );
            }
        }
    } else {
        println!("  (missing — run `make artifacts`)");
    }
    Ok(())
}

/// Deployment self-test: for every config in the artifacts dir, compile
/// the bundle, run one train step, and cross-check the fused HLO update
/// kernel against the native mirror elementwise.
fn cmd_check(args: &Args) -> Result<()> {
    use omgd::coordinator::Mask;
    use omgd::optim::{MaskedAdamW, Optimizer};
    use omgd::rng::Rng;
    use omgd::runtime::RunsScratch;

    let dir = artifacts_dir(args.get("artifacts"));
    let rt = Runtime::cpu()?;
    let mut names: Vec<String> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            let n = e.file_name().to_string_lossy().into_owned();
            n.strip_suffix(".json").map(|s| s.to_string())
        })
        .filter(|n| n != "linreg")
        .collect();
    names.sort();
    let mut failures = 0usize;
    for name in &names {
        let bundle = ModelBundle::load(&rt, &dir, name, UpdateKind::AdamW)?;
        let n = bundle.padded_len();
        let mut rng = Rng::seed_from_u64(0xC0FFEE);
        let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut dense = vec![0.0f32; n];
        for d in dense.iter_mut().take(bundle.man.total_len) {
            if rng.f64() < 0.5 {
                *d = 2.0;
            }
        }
        let mask = Mask::from_dense(dense);
        // Cross-check the fused kernel against the native mirror.
        let p0 = bundle.init_params()?;
        let (mut ph, mut m, mut v) =
            (p0.clone(), vec![0.0f32; n], vec![0.0f32; n]);
        let hp = [1e-3, 0.9, 0.999, 1e-8, 0.01, 0.1, 0.001, 0.0];
        let mut scratch = RunsScratch::new();
        bundle.adamw_update_runs(&mut ph, &g,
                                 &mask.runs().descriptors(), &mut m,
                                 &mut v, &hp, &mut scratch)?;
        let mut pn = p0.clone();
        let mut nat = MaskedAdamW::new(n, 0.9, 0.999, 1e-8, 0.01);
        nat.step(&mut pn, &g, mask.runs(), 1e-3);
        let max_dp = ph
            .iter()
            .zip(&pn)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        // One real train step must execute and return a finite loss.
        let loss = match bundle.man.kind.as_str() {
            "gpt" => {
                let b = bundle.man.data.batch * bundle.man.data.seq;
                let x = vec![1i32; b];
                bundle.train_step_lm(&p0, &x, &x)?.0
            }
            _ => {
                let x =
                    vec![0.1f32;
                         bundle.man.data.batch * bundle.man.data.d_in];
                let y = vec![0i32; bundle.man.data.batch];
                bundle.train_step_clf(&p0, &x, &y)?.0
            }
        };
        let kernel_ok = max_dp < 1e-5;
        let loss_ok = loss.is_finite() && loss > 0.0;
        if !(kernel_ok && loss_ok) {
            failures += 1;
        }
        println!(
            "{:10} kernel-vs-native max|Δp| {:.2e} [{}]  train loss \
             {:.4} [{}]",
            name,
            max_dp,
            if kernel_ok { "OK" } else { "FAIL" },
            loss,
            if loss_ok { "OK" } else { "FAIL" },
        );
    }
    if failures > 0 {
        bail!("{failures} artifact self-test(s) failed");
    }
    println!("all {} artifact bundles pass", names.len());
    Ok(())
}

fn run_config_from_args(args: &Args, model: &str) -> Result<RunConfig> {
    // Base config: --config file.toml if given, else defaults. CLI flags
    // override file values.
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            RunConfig::from_toml(&text)?
        }
        None => RunConfig::default(),
    };
    if args.get("config").is_none() || args.get("model").is_some() {
        cfg.model = model.to_string();
    }
    cfg.method = Method::parse(&args.str_or("method", cfg.method.name()))?;
    cfg.opt.family =
        OptFamily::parse(&args.str_or("opt", cfg.opt.family.name()))?;
    cfg.opt.lr = args.f64_or("lr", cfg.opt.lr)?;
    cfg.opt.weight_decay = args.f64_or("wd", cfg.opt.weight_decay)?;
    cfg.mask.keep_ratio = args.f64_or("keep-ratio", cfg.mask.keep_ratio)?;
    cfg.mask.gamma = args.usize_or("gamma", cfg.mask.gamma)?;
    cfg.mask.period = args.usize_or("period", cfg.mask.period)?;
    cfg.mask.rank = args.usize_or("rank", cfg.mask.rank)?;
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.artifacts_dir = artifacts_dir(args.get("artifacts"))
        .to_string_lossy()
        .into_owned();
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.str_or("model", "gpt-tiny");
    let mut cfg = run_config_from_args(args, &model)?;
    cfg.opt.lr = args.f64_or("lr", 6e-4)?;
    cfg.schedule = Schedule::CosineWarmup {
        warmup: args.usize_or("warmup", cfg.steps / 10)?,
        total: cfg.steps,
        min_lr: cfg.opt.lr * 0.1,
    };
    let rt = Runtime::cpu()?;
    let bundle = ModelBundle::load(
        &rt,
        std::path::Path::new(&cfg.artifacts_dir),
        &model,
        UpdateKind::AdamW,
    )?;
    let corpus = Corpus::generate(
        CorpusConfig {
            vocab: bundle.man.data.vocab,
            tokens: args.usize_or(
                "tokens",
                (bundle.man.data.seq + 1)
                    * bundle.man.data.batch
                    * cfg.steps.min(4096),
            )?,
            ..CorpusConfig::default()
        },
        bundle.man.data.seq,
    );
    println!(
        "pre-training {model} with {} ({} steps, {} windows, lr {})",
        cfg.method.name(), cfg.steps, corpus.n_samples(), cfg.opt.lr,
    );
    let out = train_lm(&bundle, &cfg, &corpus)?;
    println!(
        "done: final eval loss {:.4} | {:.2} steps/s | {:.1}s",
        out.final_metric, out.steps_per_sec, out.train_secs
    );
    if let Some(ckpt_path) = args.get("checkpoint") {
        // Final-state checkpoint (loss curve lives in --out CSV).
        let mut ckpt =
            omgd::train::Checkpoint::new(cfg.steps as u64, cfg.seed);
        ckpt.insert("params", out.final_params.clone());
        ckpt.insert("loss_tail",
                    vec![out.tail_loss(20) as f32, out.final_metric as f32]);
        ckpt.save(ckpt_path)?;
        println!("checkpoint written to {ckpt_path}");
    }
    if let Some(path) = args.get("out") {
        let mut w = CsvWriter::create(path, &["step", "loss"])?;
        for &(s, l) in &out.loss_series {
            w.row(&[s as f64, l])?;
        }
        w.flush()?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("residency") {
        omgd::metrics::write_residency_csv(path, &out.residency_series)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let task_name = args.str_or("task", "CoLA");
    let spec = omgd::data::find_task(&task_name)
        .ok_or_else(|| anyhow::anyhow!("unknown task {task_name}"))?;
    let model = args.str_or("model", "mlp-glue");
    let mut cfg = run_config_from_args(args, &model)?;

    let rt = Runtime::cpu()?;
    let bundle = ModelBundle::load(
        &rt,
        std::path::Path::new(&cfg.artifacts_dir),
        &model,
        UpdateKind::AdamW,
    )?;
    let task = ClassTask::from_spec(
        spec, bundle.man.data.d_in, bundle.man.data.n_class,
    );
    let epochs = args.usize_or("epochs", 10)?;
    let steps_per_epoch =
        task.n_train().div_ceil(bundle.man.data.batch);
    cfg.steps = epochs * steps_per_epoch;
    println!(
        "fine-tuning {} on {} with {} ({} epochs = {} steps)",
        model, task.name, cfg.method.name(), epochs, cfg.steps,
    );
    let out = train_classifier(&bundle, &cfg, &task)?;
    println!(
        "done: test acc {:.2}% | tail loss {:.4} | {:.2} steps/s",
        out.final_metric,
        out.tail_loss(20),
        out.steps_per_sec
    );
    if let Some(path) = args.get("out") {
        let mut w = CsvWriter::create(path, &["step", "loss"])?;
        for &(s, l) in &out.loss_series {
            w.row(&[s as f64, l])?;
        }
        w.flush()?;
        println!("wrote {path}");
    }
    if let Some(path) = args.get("residency") {
        omgd::metrics::write_residency_csv(path, &out.residency_series)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_illustrative(args: &Args) -> Result<()> {
    let d = args.usize_or("d", 10)?;
    let n = args.usize_or("n", 1000)?;
    let t_max = args.usize_or("t-max", 100_000)?;
    let reps = args.usize_or("reps", 3)?;
    let r = args.f64_or("r", 0.5)?;
    let seed = args.u64_or("seed", 0)?;
    let data = LinRegData::generate(d, n, seed);
    let params = QuadParams { t_max, ..QuadParams::default() };
    println!(
        "§5.1 quadratic: d={d} n={n} T={t_max} reps={reps} r={r} \
         λmin={:.3} λmax={:.3}",
        data.lambda_min, data.lambda_max
    );
    let forms = [
        GradForm::Rr,
        GradForm::RrMaskWor { r },
        GradForm::RrMaskIid { r },
        GradForm::RrProj { r },
    ];
    let mut table = TablePrinter::new(&["method", "final err²",
                                        "slope (tail)"]);
    let mut csv = args
        .get("out")
        .map(|p| {
            CsvWriter::create(
                p,
                &["method", "step", "overall", "decay", "reshuffle",
                  "compression"],
            )
        })
        .transpose()?;
    for form in forms {
        let tr = run_mean(&data, form, params, reps, seed + 1);
        let slope = loglog_slope(&tr.steps, &tr.overall, 0.5);
        table.row(vec![
            form.name().into(),
            format!("{:.3e}", tr.overall.last().unwrap()),
            format!("{slope:.2}"),
        ]);
        if let Some(w) = csv.as_mut() {
            for i in 0..tr.steps.len() {
                w.row_mixed(&[
                    omgd::metrics::CsvCell::S(form.name().into()),
                    omgd::metrics::CsvCell::I(tr.steps[i] as i64),
                    omgd::metrics::CsvCell::F(tr.overall[i]),
                    omgd::metrics::CsvCell::F(tr.decay[i]),
                    omgd::metrics::CsvCell::F(tr.reshuffle[i]),
                    omgd::metrics::CsvCell::F(tr.compression[i]),
                ])?;
            }
        }
    }
    if let Some(mut w) = csv {
        w.flush()?;
        println!("wrote {}", args.get("out").unwrap());
    }
    table.print("Figure 2 — convergence rates (slope ≈ −2 good, −1 bad)");
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    let arch_name = args.str_or("arch", "llama-7b");
    let arch = match arch_name.as_str() {
        "llama-7b" => ArchSpec::llama_7b(),
        "gpt2-124m" => ArchSpec::gpt2_124m(),
        other => bail!("unknown arch {other:?} (llama-7b | gpt2-124m)"),
    };
    let rank = args.usize_or("rank", 128)?;
    let gamma = args.usize_or("gamma", 2)?;
    println!(
        "architecture {}: {:.2}B params",
        arch.name,
        arch.total_params() as f64 / 1e9
    );
    let mut table = TablePrinter::new(&[
        "Method", "Model", "Gradients", "Optimizer", "Others", "Total",
    ]);
    for (name, policy) in [
        ("Full params", MemPolicy::Full),
        ("GaLore/GoLore", MemPolicy::Galore(rank)),
        ("LISA/LISA-wor", MemPolicy::Lisa(gamma)),
    ] {
        let b = breakdown(&arch, policy);
        table.row_f(
            name,
            &[
                MemBreakdown::gb(b.model),
                MemBreakdown::gb(b.gradients),
                MemBreakdown::gb(b.optimizer),
                MemBreakdown::gb(b.others),
                MemBreakdown::gb(b.total()),
            ],
        );
    }
    table.print(&format!(
        "Table 8 — memory breakdown (GB), {} (rank={rank}, γ={gamma})",
        arch.name
    ));
    Ok(())
}

fn grid_options_from_args(args: &Args) -> Result<GridOptions> {
    Ok(GridOptions {
        workers: args.usize_or("workers", omgd::jobs::default_workers())?,
        force: args.bool("force"),
        cache_dir: args.get("cache-dir").map(String::from),
        gc: GcPolicy {
            max_age_secs: args.opt_u64("cache-max-age-secs")?,
            max_bytes: args.opt_u64("cache-max-bytes")?,
            dry_run: false,
        },
    })
}

/// `omgd grid`: declarative sweep over methods × seeds × keep-ratios,
/// sharded across a worker pool with per-cell result caching.
fn cmd_grid(args: &Args) -> Result<()> {
    let kind = args.str_or("kind", "finetune");
    let methods: Vec<Method> = args
        .list_or("methods", "full,lisa,lisa-wor")
        .iter()
        .map(|s| Method::parse(s))
        .collect::<Result<_>>()?;
    let seeds = args.u64_list_or("seeds", &[0, 1, 2])?;
    let keeps = args.f64_list_or("keep-ratios", &[0.5])?;
    if methods.is_empty() || seeds.is_empty() || keeps.is_empty() {
        bail!("--methods/--seeds/--keep-ratios must be non-empty");
    }
    let opt_family = OptFamily::parse(&args.str_or("opt", "adamw"))?;

    let mut specs = Vec::new();
    match kind.as_str() {
        "finetune" => {
            let tasks = args.list_or("tasks", "CoLA");
            if tasks.is_empty() {
                bail!("--tasks must be non-empty");
            }
            let base = FinetuneSetup::default();
            let setup = FinetuneSetup {
                model: args.str_or("model", &base.model),
                epochs: args.usize_or("epochs", 4)?,
                lr: args.f64_or("lr", base.lr)?,
                gamma: args.usize_or("gamma", 4)?,
                period: args.usize_or("period", 1)?,
                rank: args.usize_or("rank", base.rank)?,
                ..base
            };
            let eval_epochs = args.usize_or("eval-every", 0)?;
            for method in &methods {
                for task in &tasks {
                    for &seed in &seeds {
                        for &keep_ratio in &keeps {
                            let s = FinetuneSetup {
                                seed,
                                keep_ratio,
                                ..setup.clone()
                            };
                            specs.push(finetune_spec(
                                task, *method, &s, opt_family, eval_epochs,
                            ));
                        }
                    }
                }
            }
        }
        "pretrain" => {
            // Shared builder (pretrain_config) so grid cells get the
            // same warmup+cosine schedule as the Fig. 5 driver.
            let setup = PretrainSetup {
                model: args.str_or("model", "gpt-tiny"),
                steps: args.usize_or("steps", 100)?,
                lr: args.f64_or("lr", 6e-4)?,
                gamma: args.usize_or("gamma", 2)?,
                period: args.usize_or("period", 20)?,
                seed: 0,
                eval_every: args.usize_or("eval-every", 0)?,
            };
            let rank = args.usize_or("rank", 8)?;
            for method in &methods {
                for &seed in &seeds {
                    for &keep_ratio in &keeps {
                        let s = PretrainSetup { seed, ..setup.clone() };
                        let mut cfg = pretrain_config(*method, &s);
                        cfg.opt.family = opt_family;
                        cfg.mask.keep_ratio = keep_ratio;
                        cfg.mask.rank = rank;
                        specs.push(JobSpec {
                            kind: ExperimentKind::Pretrain,
                            cfg,
                        });
                    }
                }
            }
        }
        other => bail!("unknown grid kind {other:?} (finetune | pretrain)"),
    }
    // Honor an explicit --artifacts for every cell (machine-local, so
    // outside the spec hash). Absolutized so a relative path — even one
    // spelled exactly like the config default — can't be mistaken for
    // "unset" and fall back to env/CWD resolution in the runner.
    if let Some(dir) = args.get("artifacts") {
        let p = std::path::Path::new(dir);
        let abs = if p.is_absolute() {
            p.to_path_buf()
        } else {
            std::env::current_dir()?.join(p)
        };
        let abs = abs.to_string_lossy().into_owned();
        for s in &mut specs {
            s.cfg.artifacts_dir = abs.clone();
        }
    }

    let report = if let Some(addr) = args.get("remote") {
        // Remote submission: the gateway's pool (and its remote
        // workers) run the cells; cache policy is the gateway's.
        if args.bool("force") {
            bail!(
                "--force is a server-side setting; pass it to the \
                 gateway (`omgd serve --force`), not to --remote grids"
            );
        }
        if args.get("curves").is_some() {
            bail!(
                "--curves needs per-step series, which result streams \
                 do not carry; run the grid locally (the gateway's \
                 cache makes it a replay) to export curves"
            );
        }
        let client = args.token_opt("client")?;
        let token = args.token_opt("token")?;
        println!(
            "grid: {} cells ({} methods × {} seeds × {} keep-ratios) \
             → gateway {addr}{}",
            specs.len(),
            methods.len(),
            seeds.len(),
            keeps.len(),
            client
                .as_deref()
                .map(|c| format!(" as client {c:?}"))
                .unwrap_or_default(),
        );
        run_grid_remote_auth(addr, specs, client.as_deref(), token.as_deref())?
    } else {
        let opts = grid_options_from_args(args)?;
        println!(
            "grid: {} cells ({} methods × {} seeds × {} keep-ratios), \
             {} workers{}",
            specs.len(),
            methods.len(),
            seeds.len(),
            keeps.len(),
            opts.workers,
            if opts.force { ", force" } else { "" },
        );
        run_grid(specs, &opts)?
    };
    report.print("omgd grid");
    if let Some(p) = args.get("out") {
        report.write_csv(p)?;
        println!("wrote {p}");
    }
    if let Some(p) = args.get("curves") {
        report.write_curves_csv(p)?;
        println!("wrote {p}");
    }
    if report.n_failed() > 0 {
        bail!("{} of {} grid job(s) failed", report.n_failed(),
              report.n_jobs());
    }
    Ok(())
}

/// `omgd serve`: JSONL job loop on stdin/stdout, or — with `--listen`
/// — the HTTP/1.1 gateway serving concurrent clients from one pool.
fn cmd_serve(args: &Args) -> Result<()> {
    let opts = grid_options_from_args(args)?;
    if let Some(addr) = args.get("listen") {
        let defaults = ListenOptions::default();
        let lopts = ListenOptions {
            max_conns: args.usize_or("max-conns", 64)?,
            max_in_flight: args.usize_or("max-in-flight", 32)?,
            queue_capacity: args.usize_or("queue-cap", 0)?,
            lease_secs: args.u64_or("lease-secs", defaults.lease_secs)?,
            poll_secs: args.u64_or("poll-secs", defaults.poll_secs)?,
            client_quota: args.usize_or("client-quota", 0)?,
            affinity_window: args.usize_or(
                "affinity-window",
                defaults.affinity_window,
            )?,
            keepalive_idle: std::time::Duration::from_secs(
                args.u64_or(
                    "keepalive-idle-secs",
                    defaults.keepalive_idle.as_secs(),
                )?,
            ),
            metrics: args
                .str_choice_or(
                    "metrics",
                    "full",
                    &["off", "summary", "full"],
                )?
                .parse()?,
            auth_token: serve_auth_token(args)?,
            ..defaults
        };
        let stats = omgd::jobs::serve_listen(addr, &opts, &lopts)?;
        eprintln!(
            "gateway drained: {} connection(s), {} request(s), \
             {} throttled (429), {} quota-throttled (429), \
             {} refused (503); jobs: {} accepted, {} rejected, {} ok, \
             {} failed, {} from cache; remote: {} leased \
             ({} by affinity), {} requeued, {} conflicts",
            stats.connections, stats.requests, stats.throttled,
            stats.quota_throttled, stats.refused, stats.jobs.accepted,
            stats.jobs.rejected, stats.jobs.done, stats.jobs.failed,
            stats.jobs.cached, stats.remote.leased,
            stats.remote.affinity, stats.remote.requeued,
            stats.remote.conflicts
        );
        return Ok(());
    }
    eprintln!(
        "omgd serve: {} worker(s); JSONL requests on stdin, results on \
         stdout ({{\"cmd\":\"shutdown\"}} or EOF ends)",
        opts.workers
    );
    let stdin = std::io::stdin();
    let stats =
        omgd::jobs::serve(stdin.lock(), std::io::stdout(), &opts)?;
    eprintln!(
        "serve done: {} accepted, {} rejected, {} ok, {} failed, \
         {} from cache",
        stats.accepted, stats.rejected, stats.done, stats.failed,
        stats.cached
    );
    Ok(())
}

/// Gateway bearer token: `--auth-token` wins, else `OMGD_AUTH_TOKEN`
/// from the environment (so the secret can stay out of `ps` output).
/// Both validate like every other header-bound token; an empty env var
/// counts as unset rather than as an unmatchable token.
fn serve_auth_token(args: &Args) -> Result<Option<String>> {
    if let Some(t) = args.token_opt("auth-token")? {
        return Ok(Some(t));
    }
    match std::env::var("OMGD_AUTH_TOKEN") {
        Ok(v) if v.is_empty() => Ok(None),
        Ok(v) => {
            let ok = v.len() <= 64
                && v.chars().all(|c| c.is_ascii_graphic());
            if !ok {
                bail!(
                    "OMGD_AUTH_TOKEN expects up to 64 printable \
                     non-whitespace ASCII characters"
                );
            }
            Ok(Some(v))
        }
        Err(_) => Ok(None),
    }
}

/// `omgd stats`: connect to a live gateway and pretty-print its
/// `/stats` counters, per-phase latency summaries, `/metrics` family
/// count, and — with `--events N` — the event-journal tail.
fn cmd_stats(args: &Args) -> Result<()> {
    use omgd::util::json::Json;
    use std::time::Duration;

    let addr = args.require("connect", "host:port")?;
    let timeout = Duration::from_secs(args.u64_or("timeout-secs", 10)?);
    let (code, body) = gateway_get(&addr, "/stats", timeout)?;
    if code != 200 {
        bail!("gateway {addr}: /stats returned HTTP {code}");
    }
    let j = Json::parse(&body)
        .map_err(|e| anyhow::anyhow!("unparseable /stats body: {e}"))?;
    let top =
        |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let sub = |o: &str, k: &str| {
        j.get(o)
            .and_then(|v| v.get(k))
            .and_then(Json::as_f64)
            .unwrap_or(0.0) as u64
    };
    println!("gateway {addr}");
    println!(
        "  http    {} conns ({} active), {} requests, {} throttled, \
         {} quota-throttled, {} refused",
        top("connections"),
        top("active_connections"),
        top("requests"),
        top("throttled_429"),
        top("quota_429"),
        top("refused_503"),
    );
    println!(
        "  queue   {} queued (cap {})",
        top("queue_len"),
        top("queue_capacity"),
    );
    println!(
        "  jobs    {} accepted, {} rejected, {} done, {} failed, \
         {} from cache",
        sub("jobs", "accepted"),
        sub("jobs", "rejected"),
        sub("jobs", "done"),
        sub("jobs", "failed"),
        sub("jobs", "cached"),
    );
    println!(
        "  remote  {} leased ({} by affinity), {} in flight, \
         {} requeued, {} conflicts",
        sub("remote", "leased"),
        sub("remote", "affinity"),
        sub("remote", "in_flight"),
        sub("remote", "requeued"),
        sub("remote", "conflicts"),
    );
    if let Some(phases) = j.get("phases") {
        for (label, key) in [
            ("queue-wait", "queue_wait"),
            ("sync", "sync"),
            ("run", "run"),
            ("cache-hit", "cache_hit"),
        ] {
            let Some(p) = phases.get(key) else { continue };
            let f =
                |k: &str| p.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            println!(
                "  phase   {label:10} n={:<6} mean {:>9.1}ms  \
                 p50 {:>9.1}ms  p95 {:>9.1}ms  p99 {:>9.1}ms",
                f("count") as u64,
                f("mean") * 1e3,
                f("p50") * 1e3,
                f("p95") * 1e3,
                f("p99") * 1e3,
            );
        }
    }
    match gateway_get(&addr, "/metrics", timeout) {
        Ok((200, text)) => {
            let families = text
                .lines()
                .filter(|l| l.starts_with("# TYPE "))
                .count();
            println!(
                "  metrics {families} families exported at /metrics"
            );
        }
        Ok((404, _)) => println!("  metrics disabled (--metrics off)"),
        Ok((code, _)) => println!("  metrics HTTP {code}"),
        Err(e) => println!("  metrics unreachable: {e:#}"),
    }
    if args.get("events").is_some() {
        let n = args.usize_or("events", 64)?;
        match gateway_get(&addr, &format!("/events?n={n}"), timeout) {
            Ok((200, tail)) => {
                if tail.trim().is_empty() {
                    println!("  events  (journal empty)");
                } else {
                    println!("  events  (oldest first)");
                    for line in tail.lines() {
                        println!("    {line}");
                    }
                }
            }
            Ok((404, _)) => println!(
                "  events  journal disabled (requires --metrics full)"
            ),
            Ok((code, _)) => println!("  events  HTTP {code}"),
            Err(e) => println!("  events  unreachable: {e:#}"),
        }
    }
    Ok(())
}

/// `omgd worker`: remote worker agent — lease jobs from a gateway,
/// sync missing artifacts, run them on a local pool, report results.
fn cmd_worker(args: &Args) -> Result<()> {
    let defaults = WorkerOptions::default();
    let opts = WorkerOptions {
        connect: args.require("connect", "host:port")?,
        workers: args.usize_or("workers", omgd::jobs::default_workers())?,
        worker_id: args.str_or("id", &defaults.worker_id),
        cache_dir: args.get("cache-dir").map(String::from),
        store_dir: args.get("artifact-store").map(String::from),
        force: args.bool("force"),
        max_failures: args
            .usize_or("max-failures", defaults.max_failures)?,
        max_jobs: args.usize_or("max-jobs", 0)?,
        idle_exit_secs: args.u64_or("idle-exit", 0)?,
        ckpt_period: args.usize_or("ckpt-period", 0)?,
        token: args.token_opt("token")?,
        step_threads: args.usize_or("step-threads", 0)?,
    };
    let stats = run_worker(&opts)?;
    eprintln!(
        "worker {} done: {} leased, {} ok, {} failed, {} from local \
         cache, {} artifact set(s) synced, {} conflict(s)",
        opts.worker_id, stats.leased, stats.done, stats.failed,
        stats.cached, stats.synced, stats.conflicts
    );
    Ok(())
}

/// `omgd cache-gc`: one explicit GC pass over the result cache.
fn cmd_cache_gc(args: &Args) -> Result<()> {
    let policy = GcPolicy {
        max_age_secs: args.opt_u64("max-age-secs")?,
        max_bytes: args.opt_u64("max-bytes")?,
        dry_run: args.bool("dry-run"),
    };
    if policy.is_noop() {
        bail!(
            "nothing to do: pass --max-age-secs and/or --max-bytes \
             (see docs/operations.md)"
        );
    }
    let cache = ResultCache::open(args.get("cache-dir"))?;
    // Parked checkpoints of jobs with a live journal entry must survive
    // any manual GC pass too, or a crash-recovery resume would restart
    // from step 0 (docs/durability.md).
    let jpath =
        omgd::jobs::JobJournal::path_in(cache.dir());
    let protected = omgd::jobs::journal::replay(&jpath)
        .map(|r| omgd::jobs::journal::live_hashes(&r))
        .unwrap_or_default();
    let st = cache.gc_protected(&policy, &protected)?;
    println!(
        "cache {}: scanned {} entries; {} {} ({} bytes); {} kept \
         ({} bytes)",
        cache.dir().display(),
        st.scanned,
        if policy.dry_run { "would evict" } else { "evicted" },
        st.evicted,
        st.evicted_bytes,
        st.kept,
        st.kept_bytes,
    );
    Ok(())
}

/// `omgd microbench`: native masked-AdamW steps across a keep-ratio
/// sweep {0.05, 0.25, 1.0} — the segment-run path vs the dense-bridge
/// reference — plus a mask-refresh stage (segment splice + compact
/// optimizer state remap), all on LISA-shaped masks (contiguous active
/// segments). Needs no artifacts; verifies the paths agree bitwise and
/// that nothing densified a mask, then writes a `BENCH_*.json` row so
/// the perf trajectory of both hot paths is tracked across PRs.
fn cmd_microbench(args: &Args) -> Result<()> {
    use omgd::coordinator::Mask;
    use omgd::optim::{reference::DenseAdamW, MaskedAdamW, Optimizer};
    use omgd::rng::Rng;
    use std::time::Instant;

    let n = args.usize_or("n", 1 << 16)?;
    let keep = args.f64_or("keep", 0.25)?;
    if !(keep > 0.0 && keep <= 1.0) {
        bail!("--keep must be in (0, 1]");
    }
    // 10⁴ steps / 2·10³ refreshes at scale 1; OMGD_BENCH_SCALE shrinks
    // smoke runs.
    let steps = omgd::experiments::scaled(
        args.usize_or("steps", 10_000)?,
        100,
    );
    let refreshes = omgd::experiments::scaled(
        args.usize_or("refreshes", 2_000)?,
        50,
    );
    let densify0 = omgd::obs::MASK_DENSIFY.get();

    // LISA-shaped support: `k` of the space active as contiguous
    // layer-sized segments spread over the vector.
    fn lisa_mask_for(n: usize, k: f64) -> Mask {
        let seg = (n / 64).max(1);
        let stride = ((seg as f64) / k).round() as usize;
        let mut mask = Mask::zeros(n);
        let mut off = 0usize;
        while off < n {
            mask.set_segment(off, seg.min(n - off), 2.0)
                .expect("segment in bounds");
            off += stride.max(seg);
        }
        mask
    }
    let seg = (n / 64).max(1);
    let lisa_mask = |k: f64| lisa_mask_for(n, k);

    let mut rng = Rng::seed_from_u64(1);
    let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
    let p0: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();

    let mut keeps = vec![0.05, 0.25, 1.0];
    if !keeps.iter().any(|&k| k == keep) {
        keeps.push(keep);
        keeps.sort_by(f64::total_cmp);
    }
    println!(
        "microbench: n={n}, {steps} steps per arm, keep sweep {keeps:?}"
    );
    // Per sweep point: (keep, active, runs, dense_secs, runs_secs,
    // compact state bytes).
    let mut points: Vec<(f64, usize, usize, f64, f64, usize)> =
        Vec::new();
    for &k in &keeps {
        let mask = lisa_mask(k);
        let active = mask.active_count();

        let mut p = p0.clone();
        let mut dense = DenseAdamW::default_hp(n);
        let t0 = Instant::now();
        for _ in 0..steps {
            dense.step(&mut p, &g, mask.dense_bridge(), 1e-4);
        }
        let dense_secs = t0.elapsed().as_secs_f64();

        let mut pr = p0.clone();
        let mut compact = MaskedAdamW::default_hp(n);
        let t1 = Instant::now();
        for _ in 0..steps {
            compact.step(&mut pr, &g, mask.runs(), 1e-4);
        }
        let runs_secs = t1.elapsed().as_secs_f64();

        // The two paths must agree bitwise — a fast wrong answer is
        // not a benchmark result.
        if p.iter().zip(&pr).any(|(a, b)| a.to_bits() != b.to_bits()) {
            bail!(
                "runs path diverged from the dense bridge at keep {k}"
            );
        }
        println!(
            "  keep {k:<5} dense {:8.1} ms  runs {:8.1} ms  {:4.2}x \
             ({} runs, {active} active)",
            dense_secs * 1e3,
            runs_secs * 1e3,
            dense_secs / runs_secs.max(1e-12),
            mask.runs().runs().len(),
        );
        points.push((
            k,
            active,
            mask.runs().runs().len(),
            dense_secs,
            runs_secs,
            compact.state_bytes(),
        ));
    }

    // Mask-refresh stage: the period-boundary work — a segment splice
    // plus the compact optimizer's active-region state remap — which
    // must never materialize a dense vector.
    let mut mask = lisa_mask(keep);
    let mut compact = MaskedAdamW::default_hp(n);
    compact.on_mask_refresh(mask.runs());
    let win = (n - seg) / 2;
    let t2 = Instant::now();
    for i in 0..refreshes {
        let scale = if i % 2 == 0 { 0.0 } else { 2.0 };
        mask.set_segment(win, seg, scale).expect("segment in bounds");
        compact.on_mask_refresh(mask.runs());
    }
    let refresh_secs = t2.elapsed().as_secs_f64();
    println!(
        "  refresh {:8.1} ms for {refreshes} splice+remap cycles \
         ({:.1} µs each)",
        refresh_secs * 1e3,
        refresh_secs * 1e6 / (refreshes as f64).max(1.0),
    );

    // Thread-sweep stage: the shard-parallel native step against the
    // serial walk at {1, 2, 4} threads × keep {0.05, 0.25}. Every arm
    // is bitwise-verified before its timing counts (a 3-step check up
    // front, and the full timed trajectory compared after) — a fast
    // wrong answer is not a benchmark result. `tn` is floored at 2¹⁸
    // so the active set clears `exec::PAR_MIN_ACTIVE` at both keeps.
    use omgd::exec::ExecEngine;
    let tn = n.max(1 << 18);
    let tsteps = omgd::experiments::scaled(
        args.usize_or("sweep-steps", 1_000)?,
        20,
    );
    println!(
        "thread sweep: n={tn}, {tsteps} steps per arm, threads [1, 2, 4]"
    );
    let mut rng2 = Rng::seed_from_u64(2);
    let gt: Vec<f32> = (0..tn).map(|_| rng2.normal32()).collect();
    let pt0: Vec<f32> = (0..tn).map(|_| rng2.normal32()).collect();
    // Per sweep arm: (threads, keep, active, serial_secs, par_secs).
    let mut tsweep: Vec<(usize, f64, usize, f64, f64)> = Vec::new();
    for &k in &[0.05f64, 0.25] {
        let mask = lisa_mask_for(tn, k);
        let active = mask.active_count();

        let mut ps = pt0.clone();
        let mut os = MaskedAdamW::default_hp(tn);
        let t = Instant::now();
        for _ in 0..tsteps {
            os.step(&mut ps, &gt, mask.runs(), 1e-4);
        }
        let serial_secs = t.elapsed().as_secs_f64();

        for &th in &[1usize, 2, 4] {
            let pool = ExecEngine::new(th);
            let (mut pa, mut pb) = (pt0.clone(), pt0.clone());
            let mut oa = MaskedAdamW::default_hp(tn);
            let mut ob = MaskedAdamW::default_hp(tn);
            for _ in 0..3 {
                oa.step(&mut pa, &gt, mask.runs(), 1e-4);
                ob.step_sharded(&mut pb, &gt, mask.runs(), 1e-4, &pool);
            }
            if pa.iter().zip(&pb).any(|(a, b)| a.to_bits() != b.to_bits())
            {
                bail!("sharded step diverged at {th} threads, keep {k}");
            }
            let mut pp = pt0.clone();
            let mut op = MaskedAdamW::default_hp(tn);
            let t = Instant::now();
            for _ in 0..tsteps {
                op.step_sharded(&mut pp, &gt, mask.runs(), 1e-4, &pool);
            }
            let par_secs = t.elapsed().as_secs_f64();
            if ps.iter().zip(&pp).any(|(a, b)| a.to_bits() != b.to_bits())
            {
                bail!(
                    "sharded trajectory diverged at {th} threads, \
                     keep {k}"
                );
            }
            println!(
                "  keep {k:<5} threads {th}  serial {:8.1} ms  sharded \
                 {:8.1} ms  {:4.2}x ({active} active)",
                serial_secs * 1e3,
                par_secs * 1e3,
                serial_secs / par_secs.max(1e-12),
            );
            tsweep.push((th, k, active, serial_secs, par_secs));
        }
    }

    // The whole bench must finish without one dense→runs rescan — the
    // steady-state contract `omgd_mask_densify_total` exists to keep.
    let densified = omgd::obs::MASK_DENSIFY.get() - densify0;
    if densified != 0 {
        bail!(
            "microbench densified a mask ({densified} scans): the \
             steady-state path regressed"
        );
    }

    // Run metadata so the BENCH trajectory is attributable: which
    // revision produced the point, at what smoke scale, on how wide a
    // machine, and when. A checkout without git still benches.
    let rev = std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty() && s.chars().all(|c| c.is_ascii_hexdigit()))
        .unwrap_or_else(|| "unknown".to_string());
    let unix_secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    // The top-level keys keep their historical meaning (the `--keep`
    // point) so ci.sh's trajectory gate compares like with like across
    // revisions; the sweep rides along under short, non-colliding keys.
    let (_, active, _, dense_secs, runs_secs, state_bytes) = *points
        .iter()
        .find(|pt| pt.0 == keep)
        .expect("--keep is in the sweep");
    let ratio = dense_secs / runs_secs.max(1e-12);
    let sweep_json = points
        .iter()
        .map(|&(k, a, nr, ds, rs, _)| {
            format!(
                "{{\"k\":{k},\"a\":{a},\"nr\":{nr},\
                 \"dense_s\":{ds:.6},\"runs_s\":{rs:.6}}}"
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    let tsweep_json = tsweep
        .iter()
        .map(|&(th, k, a, ss, ps)| {
            format!(
                "{{\"threads\":{th},\"k\":{k},\"a\":{a},\
                 \"serial_s\":{ss:.6},\"par_s\":{ps:.6},\
                 \"speedup\":{:.4}}}",
                ss / ps.max(1e-12)
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    let out = args.str_or("out", "BENCH_maskruns.json");
    std::fs::write(
        &out,
        format!(
            "{{\"bench\":\"maskruns\",\"n\":{n},\"keep\":{keep},\
             \"active\":{active},\"steps\":{steps},\
             \"dense_secs\":{dense_secs:.6},\
             \"runs_secs\":{runs_secs:.6},\"ratio\":{ratio:.4},\
             \"state_bytes\":{state_bytes},\"dense_state_bytes\":{},\
             \"refreshes\":{refreshes},\
             \"refresh_secs\":{refresh_secs:.6},\
             \"rev\":\"{rev}\",\"scale\":{},\"workers\":{},\
             \"unix_secs\":{unix_secs},\"sweep\":[{sweep_json}],\
             \"tn\":{tn},\"tsteps\":{tsteps},\
             \"tsweep\":[{tsweep_json}]}}\n",
            2 * n * 4,
            omgd::experiments::bench_scale(),
            omgd::jobs::default_workers(),
        ),
    )?;
    println!("wrote {out}");
    Ok(())
}
