//! # OMGD — Omni-Masked Gradient Descent (reproduction)
//!
//! Production-shaped reproduction of *"Omni-Masked Gradient Descent:
//! Memory-Efficient Optimization via Mask Traversal with Improved
//! Convergence"* as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the training coordinator: Algorithm 1's
//!   `[M]×[N]` without-replacement traversal ([`coordinator`]), the
//!   LISA/LISA-WOR layer scheduler (Algorithm 2) — masks carried as
//!   canonical segment runs ([`coordinator::MaskRuns`]), runs-first
//!   end to end: native masked steps, residency accounting, and the
//!   HLO dispatch all consume `(offset, len, scale)` runs, O(active)
//!   not O(d), while the dense vector is a lazy, explicitly requested
//!   bridge (`Mask::dense_bridge`) — runs-first native optimizers
//!   with active-region-only moment state ([`optim`]), the analytic
//!   memory model ([`memory`]), the
//!   §5.1 quadratic testbed ([`quadratic`]), data pipelines ([`data`]),
//!   the PJRT runtime ([`runtime`]) that executes AOT-compiled HLO, and
//!   the job-orchestration subsystem ([`jobs`]): hashed [`jobs::JobSpec`]
//!   grid cells sharded across a panic-isolated worker pool, with an
//!   on-disk result cache (true-LRU age/size GC), transport-agnostic
//!   serve sessions over a shared [`jobs::JobHub`], the HTTP/1.1
//!   gateway ([`jobs::net`], `omgd serve --listen`), and distributed
//!   execution over that gateway ([`jobs::remote`] /
//!   [`jobs::sync`]: `omgd worker --connect` lease-pull agents with
//!   content-addressed artifact sync, `omgd grid --remote`
//!   submission).
//! * **L2 (python/compile, build-time)** — JAX models over a flat
//!   parameter vector, lowered once to HLO text.
//! * **L1 (python/compile/kernels, build-time)** — Pallas masked-update
//!   kernels fused into the L2 HLO.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `omgd` binary is self-contained.
//!
//! Since the workspace split this crate is a facade: the numerics live
//! in `omgd-core`, shared plumbing in `omgd-util`, job orchestration
//! in `omgd-jobs`, and the training engine in `omgd-train`. The
//! historical module paths (`omgd::jobs`, `omgd::train`, ...) are
//! preserved here by re-export so downstream code is untouched.

pub use omgd_core::{coordinator, data, exec, linalg, memory, optim, prop, rng, runtime};
pub use omgd_train::{experiments, quadratic, train};
pub use omgd_util::{bench, cli, config, manifest, metrics, obs, util};

/// Job orchestration under its historical path, with the
/// trainer-backed entry points (`run_grid`, `serve`, `serve_listen`,
/// `run_worker`, `cached_runner`) grafted back in from
/// `omgd_train::runner` — the workspace split moved their concrete
/// implementations behind the [`omgd_jobs::JobExecutor`] seam, but the
/// public surface stays `omgd::jobs::*`.
pub mod jobs {
    pub use omgd_jobs::*;
    pub use omgd_train::runner::{cached_runner, run_grid, run_worker, serve, serve_listen};
}
