//! Lifecycle-authority property tests: random event interleavings
//! against [`Lifecycle`], checked two ways.
//!
//! 1. **Oracle equivalence** — `Lifecycle::apply` must behave exactly
//!    like the pure [`next_state`] function folded over a shadow map:
//!    same accepted states, same typed refusals, and a refused event
//!    never mutates the table.
//! 2. **Journal equivalence** — mirroring each *accepted* transition
//!    into the crash journal exactly the way the gateway does
//!    (`Admit`/`Lease`/`Renew` as they happen, `Done` at finalize,
//!    `Cancel` at cancel; queue membership and expiry are in-memory
//!    only) and replaying it must classify every seq the same way the
//!    live automaton does: finalized ↔ completed, cancelled ↔ gone,
//!    anything else admitted ↔ pending (so a crash re-dispatches it).
//!
//! The exhaustive legal/illegal transition table itself is asserted
//! unit-style inside `omgd-jobs::lifecycle`; these tests cover the
//! *paths* — arbitrary orderings, duplicate deliveries, wrong-worker
//! claims — that no table enumeration reaches.

use omgd::config::RunConfig;
use omgd::jobs::journal::{self, Record};
use omgd::jobs::lifecycle::next_state;
use omgd::jobs::{
    ExperimentKind, JobEvent, JobOutcome, JobSpec, JobState, JobStatus,
    Lifecycle,
};
use omgd::prop::{check, Gen};
use std::collections::HashMap;

fn spec_for(seq: u64) -> JobSpec {
    let mut cfg = RunConfig::default();
    cfg.seed = seq;
    JobSpec {
        kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 1 },
        cfg,
    }
}

fn outcome_for(seq: u64) -> JobOutcome {
    JobOutcome {
        final_metric: seq as f64,
        tail_loss: 0.5,
        steps: 2,
        train_secs: 0.1,
        loss_series: vec![(0, 1.0)],
        eval_series: vec![],
    }
}

/// One random event aimed at one of a small pool of seqs. Workers are
/// drawn from a pool of two so wrong-worker renews/reports occur
/// naturally.
fn random_event(g: &mut Gen) -> JobEvent {
    match g.usize_in(0, 9) {
        0 => JobEvent::Admit,
        1 => JobEvent::Enqueue,
        2 => JobEvent::Lease("w-0".into()),
        3 => JobEvent::Lease("w-1".into()),
        4 => JobEvent::Renew(
            if g.bool() { "w-0".into() } else { "w-1".into() },
        ),
        5 => {
            let named = g.bool();
            let wrong = g.bool();
            JobEvent::Report(named.then(|| {
                if wrong { "w-1".into() } else { "w-0".into() }
            }))
        }
        6 => JobEvent::Expire,
        7 => JobEvent::Cancel,
        8 => JobEvent::Finalize,
        _ => {
            if g.bool() {
                JobEvent::ReplayPending
            } else {
                JobEvent::ReplayDone
            }
        }
    }
}

#[test]
fn prop_lifecycle_apply_matches_pure_transition_oracle() {
    check("lifecycle apply ≡ next_state oracle", 60, |g| {
        let lc = Lifecycle::new();
        let mut shadow: HashMap<u64, JobState> = HashMap::new();
        for _ in 0..g.usize_in(1, 120) {
            let seq = g.usize_in(0, 5) as u64;
            let ev = random_event(g);
            let expected = next_state(shadow.get(&seq), &ev);
            let got = lc.apply(seq, &ev);
            assert_eq!(got, expected, "seq {seq}, event {ev:?}");
            match expected {
                Ok(st) => {
                    shadow.insert(seq, st);
                }
                Err(_) => {
                    // A refusal must leave the table untouched.
                    assert_eq!(
                        lc.state(seq),
                        shadow.get(&seq).cloned(),
                        "refused event mutated seq {seq}"
                    );
                }
            }
        }
        // Terminal bookkeeping agrees with the shadow map.
        let want: Vec<u64> = {
            let mut v: Vec<u64> = shadow
                .iter()
                .filter(|(_, s)| s.is_terminal())
                .map(|(&k, _)| k)
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(lc.terminal_seqs(), want);
        assert_eq!(lc.len(), shadow.len());
    });
}

#[test]
fn prop_accepted_transitions_replay_to_same_terminal_states() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    check("lifecycle journal replay equivalence", 40, |g| {
        let lc = Lifecycle::new();
        let mut recs: Vec<Record> = Vec::new();
        let n_seqs = g.usize_in(1, 6) as u64;
        for _ in 0..g.usize_in(1, 140) {
            let seq = g.usize_in(0, n_seqs as usize - 1) as u64;
            let ev = random_event(g);
            // Replay-only events model a *restart*; the journal the
            // gateway writes never contains them, so keep this history
            // to the live-gateway alphabet.
            if matches!(ev, JobEvent::ReplayPending | JobEvent::ReplayDone) {
                continue;
            }
            let Ok(_) = lc.apply(seq, &ev) else { continue };
            // Mirror the accepted transition the way serve.rs journals
            // it. Enqueue/Expire are deliberately unjournaled: queue
            // membership and leases die with the process.
            match &ev {
                JobEvent::Admit => recs.push(Record::Admit {
                    seq,
                    priority: 0,
                    client: None,
                    spec: spec_for(seq),
                }),
                JobEvent::Lease(w) => recs
                    .push(Record::Lease { seq, worker: w.clone() }),
                JobEvent::Renew(w) => recs
                    .push(Record::Renew { seq, worker: w.clone() }),
                JobEvent::Finalize => recs.push(Record::Done {
                    seq,
                    status: JobStatus::Done(outcome_for(seq)),
                    from_cache: false,
                    secs: 0.1,
                    spec: spec_for(seq),
                }),
                JobEvent::Cancel => recs.push(Record::Cancel { seq }),
                JobEvent::Enqueue
                | JobEvent::Report(_)
                | JobEvent::Expire => {}
                JobEvent::ReplayPending | JobEvent::ReplayDone => {
                    unreachable!()
                }
            }
        }
        let path = std::env::temp_dir().join(format!(
            "omgd-lifecycle-replay-{}-{}.log",
            std::process::id(),
            CASE.fetch_add(1, Ordering::Relaxed),
        ));
        let lines: Vec<String> =
            recs.iter().map(Record::encode_line).collect();
        std::fs::write(&path, lines.concat()).unwrap();
        let rep = journal::replay(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let pending: Vec<u64> =
            rep.pending.iter().map(|p| p.seq).collect();
        let completed: Vec<u64> =
            rep.completed.iter().map(|r| r.seq).collect();
        for seq in 0..n_seqs {
            match lc.state(seq) {
                // Finalized jobs survive a crash as completed results.
                Some(JobState::Done) => {
                    assert!(completed.contains(&seq), "seq {seq} done");
                    assert!(!pending.contains(&seq), "seq {seq} done");
                }
                // Cancelled jobs vanish entirely.
                Some(JobState::Cancelled) => {
                    assert!(!completed.contains(&seq), "seq {seq}");
                    assert!(!pending.contains(&seq), "seq {seq}");
                }
                // Everything else the authority admitted must come
                // back pending so a restart re-dispatches it —
                // including Reported-but-unfinalized (its result was
                // never durably dispatched) and expired leases.
                Some(_) => {
                    assert!(
                        pending.contains(&seq),
                        "live seq {seq} ({:?}) lost by replay",
                        lc.state(seq)
                    );
                    assert!(!completed.contains(&seq), "seq {seq}");
                }
                // Never admitted: the journal cannot know it.
                None => {
                    assert!(!pending.contains(&seq), "seq {seq}");
                    assert!(!completed.contains(&seq), "seq {seq}");
                }
            }
        }
        // Replaying the journal into a fresh authority (what serve
        // startup does) lands every job in a legal, expected state.
        let lc2 = Lifecycle::new();
        for p in &rep.pending {
            assert_eq!(
                lc2.apply(p.seq, &JobEvent::ReplayPending),
                Ok(JobState::Queued)
            );
        }
        for r in &rep.completed {
            assert_eq!(
                lc2.apply(r.seq, &JobEvent::ReplayDone),
                Ok(JobState::Done)
            );
        }
        assert_eq!(lc2.len(), pending.len() + completed.len());
    });
}
