//! Property-based tests on coordinator / optimizer invariants, using the
//! in-repo prop framework (rust/crates/omgd-core/src/prop.rs). Each property runs across
//! dozens of randomized cases; failures report a replayable seed.

use omgd::coordinator::{DataSampler, LisaScheduler, LisaVariant, Mask,
                        MaskRuns, MaskSet, OmgdCycle};
use omgd::exec::ExecEngine;
use omgd::linalg::{stiefel, Mat};
use omgd::manifest::{Manifest, ParamInfo};
use omgd::optim::reference::{DenseAdamW, DenseSgdm};
use omgd::optim::{galore, MaskedAdamW, MaskedSgd, MaskedSgdm, Optimizer,
                  SiftOptimizer};
use omgd::prop::{check, Gen};

use omgd::config::RunConfig;
use omgd::jobs::journal::{self, Record};
use omgd::jobs::{ExperimentKind, JobOutcome, JobSpec, JobStatus};
use omgd::util::json::Json;
use std::collections::HashSet;
use std::path::Path;

/// Random toy manifest: `k` middle layers of random sizes plus
/// embed/head, padded to a block multiple.
fn random_manifest(g: &mut Gen) -> Manifest {
    let k = g.usize_in(2, 6);
    let block = 8usize;
    let mut params = Vec::new();
    let mut off = 0usize;
    let push = |params: &mut Vec<ParamInfo>, name: String,
                    layer: String, len: usize, off: &mut usize| {
        params.push(ParamInfo {
            name,
            shape: vec![len],
            layer,
            offset: *off,
            len,
        });
        *off += len;
    };
    push(&mut params, "in_w".into(), "embed".into(), g.usize_in(2, 10),
         &mut off);
    for i in 0..k {
        push(&mut params, format!("block_{i}.w"), format!("block_{i}"),
             g.usize_in(2, 12), &mut off);
    }
    push(&mut params, "out_w".into(), "head".into(), g.usize_in(2, 10),
         &mut off);
    let total = off;
    let padded = total.div_ceil(block) * block;
    // Build through JSON so the same validation path is exercised.
    let params_json: Vec<String> = params
        .iter()
        .map(|p| {
            format!(
                r#"{{"name":"{}","shape":[{}],"layer":"{}","offset":{},"len":{}}}"#,
                p.name, p.len, p.layer, p.offset, p.len
            )
        })
        .collect();
    let text = format!(
        r#"{{"name":"prop","kind":"mlp","block":{block},
"total_len":{total},"padded_len":{padded},
"params":[{}],
"data":{{"batch":2}},
"artifacts":{{"train":"t","eval":"e","init":"i",
"update":{{"adamw":"a","sgdm":"s"}}}}}}"#,
        params_json.join(",")
    );
    Manifest::from_json(&Json::parse(&text).unwrap(), Path::new("/tmp"))
        .unwrap()
}

#[test]
fn prop_coordinate_partition_always_satisfies_eq3() {
    check("coordinate partition eq3", 40, |g| {
        let total = g.usize_in(10, 200);
        let n = total + g.usize_in(0, 32);
        let r = *g.pick(&[0.2, 0.25, 1.0 / 3.0, 0.5, 0.7]);
        let mut rng = g.rng.split(1);
        let set = MaskSet::coordinate_partition(n, total, r, &mut rng);
        let m = (1.0f64 / r).ceil() as usize;
        assert_eq!(set.m(), m);
        let c = set.coverage_scalar(total)
            .expect("coverage must be a scalar multiple of 1");
        assert!((c - m as f32).abs() < 1e-4, "c={c} m={m}");
        // disjointness
        for i in 0..total {
            let owners =
                set.masks.iter().filter(|mk| mk.value(i) != 0.0).count();
            assert_eq!(owners, 1, "coord {i}");
        }
        // padding untouched
        for mk in &set.masks {
            assert!(mk.dense_bridge()[total..].iter().all(|&v| v == 0.0));
        }
    });
}

#[test]
fn prop_tensor_partition_eq3_and_alignment() {
    check("tensor partition eq3", 40, |g| {
        let man = random_manifest(g);
        let r = *g.pick(&[0.25, 0.5, 1.0 / 3.0]);
        let mut rng = g.rng.split(2);
        let set = MaskSet::tensor_partition(&man, r, &mut rng).unwrap();
        let c = set.coverage_scalar(man.total_len).expect("eq3 violated");
        assert!((c - set.m() as f32).abs() < 1e-4);
        // tensor alignment: each tensor wholly in exactly one mask
        for p in &man.params {
            let owners = set
                .masks
                .iter()
                .filter(|mk| mk.value(p.offset) != 0.0)
                .count();
            assert_eq!(owners, 1, "{}", p.name);
            for mk in &set.masks {
                let seg = &mk.dense_bridge()[p.offset..p.offset + p.len];
                assert!(seg.iter().all(|&v| v == seg[0]),
                        "{} split across masks", p.name);
            }
        }
    });
}

#[test]
fn prop_omgd_cycle_is_exact_cover() {
    check("omgd cycle exact cover", 30, |g| {
        let m = g.usize_in(1, 6);
        let n = g.usize_in(1, 20);
        let mut rng = g.rng.split(3);
        let mut cyc = OmgdCycle::new(m, n);
        for _ in 0..2 {
            let mut seen = HashSet::new();
            for _ in 0..m * n {
                let (p, _) = cyc.next(&mut rng);
                assert!(p.mask < m && p.sample < n);
                assert!(seen.insert((p.mask, p.sample)));
            }
            assert_eq!(seen.len(), m * n);
        }
    });
}

#[test]
fn prop_lisa_wor_cycle_covers_pool_without_repeats() {
    check("lisa wor coverage", 40, |g| {
        let nl = g.usize_in(2, 16);
        let gamma = g.usize_in(1, nl);
        let mut rng = g.rng.split(4);
        let mut sched = LisaScheduler::new(
            LisaVariant::LisaWor,
            (0..nl).map(|i| format!("block_{i}")).collect(),
            gamma,
        );
        // Walk periods; within a pool traversal no layer repeats.
        let mut seen: HashSet<String> = HashSet::new();
        for _ in 0..(3 * nl.div_ceil(gamma)) {
            let act = sched.next_period(&mut rng);
            if act.new_cycle {
                seen.clear();
            }
            for l in &act.layers {
                assert!(seen.insert(l.clone()),
                        "repeat {l} (nl={nl}, γ={gamma})");
            }
            if seen.len() == nl {
                seen.clear();
            }
        }
    });
}

#[test]
fn prop_rr_sampler_epochs_are_permutations() {
    check("rr sampler permutations", 30, |g| {
        let n = g.usize_in(1, 64);
        let mut rng = g.rng.split(5);
        let mut s = DataSampler::rr(n);
        for _ in 0..3 {
            let mut seen = HashSet::new();
            for _ in 0..n {
                let (i, _) = s.next(&mut rng);
                assert!(seen.insert(i));
            }
        }
    });
}

#[test]
fn prop_masked_adamw_only_touches_active() {
    check("adamw hard freeze", 30, |g| {
        let n = g.usize_in(4, 256);
        let p0 = g.vec_f32(n, 1.0);
        let grad = g.vec_f32(n, 1.0);
        let mut dense = vec![0.0f32; n];
        for v in dense.iter_mut() {
            if g.bool() {
                *v = *g.pick(&[1.0f32, 2.0, 4.0]);
            }
        }
        let mask = Mask::from_dense(dense);
        let mut p = p0.clone();
        let mut opt = MaskedAdamW::default_hp(n);
        opt.step(&mut p, &grad, mask.runs(), 1e-2);
        for i in 0..n {
            if mask.value(i) == 0.0 {
                assert_eq!(p[i], p0[i], "frozen coord {i} moved");
                assert!(opt.moment_at(i).is_none(),
                        "frozen coord {i} holds state");
            } else if grad[i] != 0.0 {
                assert_ne!(p[i], p0[i], "active coord {i} frozen");
            }
        }
        assert_eq!(opt.resident(), mask.active_count());
    });
}

#[test]
fn prop_masked_sgdm_momentum_norm_bounded() {
    check("sgdm buffer bounded", 20, |g| {
        let n = g.usize_in(4, 128);
        let mut p = g.vec_f32(n, 0.5);
        let mut opt = MaskedSgdm::new(n, 0.9, 0.0, false);
        let mask = Mask::ones(n);
        // constant unit gradient: buf → 1/(1−μ) = 10, never beyond
        let grad = vec![1.0f32; n];
        for _ in 0..200 {
            opt.step(&mut p, &grad, mask.runs(), 1e-4);
        }
        assert!(opt.buf().iter().all(|&b| b <= 10.0 + 1e-3),
                "momentum exceeded geometric bound");
    });
}

#[test]
fn prop_stiefel_columns_orthonormal() {
    check("stiefel orthonormal", 20, |g| {
        let m = g.usize_in(2, 24);
        let k = g.usize_in(1, m);
        let mut rng = g.rng.split(6);
        let p = stiefel(m, k, &mut rng);
        let ptp = p.transpose().matmul(&p);
        let err = ptp.sub(&Mat::eye(k)).fro();
        assert!(err < 1e-9, "PᵀP−I fro {err} (m={m} k={k})");
    });
}

#[test]
fn prop_layerwise_mask_respects_always_active_set() {
    check("layerwise mask", 30, |g| {
        let man = random_manifest(g);
        let middles = man.middle_layers();
        let pick = g.usize_in(0, middles.len() - 1);
        let active = vec![middles[pick].clone()];
        let scale = middles.len() as f32;
        let mask = MaskSet::layerwise(&man, &active, scale).unwrap();
        for p in &man.params {
            let seg = &mask.dense_bridge()[p.offset..p.offset + p.len];
            let want = if p.layer == "embed" || p.layer == "head" {
                1.0
            } else if p.layer == active[0] {
                scale
            } else {
                0.0
            };
            assert!(seg.iter().all(|&v| v == want),
                    "{}: got {:?} want {want}", p.name, seg[0]);
        }
    });
}

#[test]
fn prop_cycle_masked_gradient_sums_match_scaled_full() {
    // The cancellation behind Lemma 4.4 at fixed θ: summing the masked
    // gradients over a full [M]×[N] cycle equals M × Σᵢ ∇f(θ; zᵢ).
    check("lemma 4.4 cancellation", 20, |g| {
        let d = g.usize_in(3, 12);
        let n = g.usize_in(2, 10);
        let r = *g.pick(&[0.25, 0.5]);
        let mut rng = g.rng.split(7);
        let grads: Vec<Vec<f32>> =
            (0..n).map(|_| g.vec_f32(d, 1.0)).collect();
        let set = MaskSet::coordinate_partition(d, d, r, &mut rng);
        let m = set.m();
        let mut cyc = OmgdCycle::new(m, n);
        let mut acc = vec![0.0f64; d];
        for _ in 0..m * n {
            let (pair, _) = cyc.next(&mut rng);
            let mask = &set.masks[pair.mask];
            for i in 0..d {
                acc[i] +=
                    (mask.value(i) * grads[pair.sample][i]) as f64;
            }
        }
        for i in 0..d {
            let want: f64 = m as f64
                * grads.iter().map(|gr| gr[i] as f64).sum::<f64>();
            assert!((acc[i] - want).abs() < 1e-3 * (1.0 + want.abs()),
                    "coord {i}: {} vs {want}", acc[i]);
        }
    });
}

// -------------------------------------------------------------------------
// Runs-first API contract: the single runs `step` must be bitwise
// equivalent to dense-vector semantics (driven through the lazy
// `dense_bridge()` / the reference mirrors) for every optimizer, across
// keep ratios {0.05, 0.25, 0.5, 1.0} and both mask shapes.
// -------------------------------------------------------------------------

/// Random mask over `n` coords mixing segment and scattered structure,
/// with a keep ratio drawn from the given roster.
fn random_mask(g: &mut Gen, n: usize) -> Mask {
    let keep = *g.pick(&[0.05f64, 0.25, 0.5, 1.0]);
    let mut dense = vec![0.0f32; n];
    if g.bool() {
        // segment-structured (LISA/tensorwise shape)
        let seg = g.usize_in(1, (n / 4).max(1));
        let mut off = 0usize;
        while off < n {
            if g.rng.f64() < keep {
                let scale = *g.pick(&[1.0f32, 2.0, 4.0]);
                for d in dense.iter_mut().skip(off).take(seg) {
                    *d = scale;
                }
            }
            off += seg;
        }
    } else {
        // scattered coordinates (coordinate-partition shape)
        let scale = *g.pick(&[1.0f32, 2.0, 4.0]);
        for d in dense.iter_mut() {
            if g.rng.f64() < keep {
                *d = scale;
            }
        }
    }
    Mask::from_dense(dense)
}

#[test]
fn prop_adamw_runs_step_bitwise_equals_dense_reference() {
    check("adamw runs == dense", 40, |g| {
        let n = g.usize_in(8, 300);
        let mask = random_mask(g, n);
        let p0 = g.vec_f32(n, 1.0);
        let (mut pd, mut pr) = (p0.clone(), p0);
        let mut dense = DenseAdamW::default_hp(n);
        let mut compact = MaskedAdamW::default_hp(n);
        for _ in 0..3 {
            let grad = g.vec_f32(n, 1.0);
            dense.step(&mut pd, &grad, mask.dense_bridge(), 1e-3);
            compact.step(&mut pr, &grad, mask.runs(), 1e-3);
        }
        for i in 0..n {
            assert_eq!(pd[i].to_bits(), pr[i].to_bits(), "coord {i}");
        }
        // residency claim: exactly the active region
        assert_eq!(compact.state_bytes(), mask.active_count() * 8);
    });
}

#[test]
fn prop_sgdm_runs_step_bitwise_equals_dense_reference() {
    check("sgdm runs == dense", 40, |g| {
        let n = g.usize_in(8, 300);
        let mask = random_mask(g, n);
        let nesterov = g.bool();
        let p0 = g.vec_f32(n, 1.0);
        let (mut pd, mut pr) = (p0.clone(), p0);
        let mut dense = DenseSgdm::new(n, 0.9, 1e-4, nesterov);
        let mut compact = MaskedSgdm::new(n, 0.9, 1e-4, nesterov);
        for _ in 0..3 {
            let grad = g.vec_f32(n, 1.0);
            dense.step(&mut pd, &grad, mask.dense_bridge(), 0.05);
            compact.step(&mut pr, &grad, mask.runs(), 0.05);
        }
        for i in 0..n {
            assert_eq!(pd[i].to_bits(), pr[i].to_bits(), "coord {i}");
        }
        assert_eq!(compact.state_bytes(), mask.active_count() * 4);
    });
}

#[test]
fn prop_sgd_runs_step_bitwise_equals_dense_emulation() {
    check("sgd runs == dense", 40, |g| {
        let n = g.usize_in(8, 300);
        let mask = random_mask(g, n);
        let p0 = g.vec_f32(n, 1.0);
        let grad = g.vec_f32(n, 1.0);
        let (mut pd, mut pr) = (p0.clone(), p0);
        // dense emulation over the lazy bridge, same arithmetic order
        // as the run walk (lr * scale * g)
        for (i, &mk) in mask.dense_bridge().iter().enumerate() {
            if mk != 0.0 {
                pd[i] -= 0.1 * mk * grad[i];
            }
        }
        MaskedSgd.step(&mut pr, &grad, mask.runs(), 0.1);
        for i in 0..n {
            assert_eq!(pd[i].to_bits(), pr[i].to_bits(), "coord {i}");
        }
    });
}

#[test]
fn prop_golore_galore_runs_mask_equals_gradient_gating() {
    // The merge-walk (mask runs ∩ dense fallback segments) must equal
    // per-coordinate gating in gradient space: arm B runs the same
    // optimizer under a full mask with the fallback-segment gradient
    // pre-scaled by the mask. Projected tensors ignore the mask in
    // both arms (the projection consumes the raw 2-D gradient), so
    // every unfrozen coordinate must match bitwise, and mask-frozen
    // fallback coordinates must not move at all.
    check("golore/galore mask == grad gating", 15, |g| {
        let rows = g.usize_in(6, 12);
        let cols = g.usize_in(6, 12);
        let blen = g.usize_in(2, 10);
        let n = rows * cols + blen;
        let params = vec![
            ParamInfo {
                name: "w".into(),
                shape: vec![rows, cols],
                layer: "block_0".into(),
                offset: 0,
                len: rows * cols,
            },
            ParamInfo {
                name: "b".into(),
                shape: vec![blen],
                layer: "block_0".into(),
                offset: rows * cols,
                len: blen,
            },
        ];
        let rank = 2;
        let mask = random_mask(g, n);
        let full = Mask::ones(n);
        let p0 = g.vec_f32(n, 0.5);
        for ctor in [galore::golore, galore::galore] {
            let mut oa = ctor(&params, n, rank, 2, 7);
            let mut ob = ctor(&params, n, rank, 2, 7);
            let (mut pa, mut pb) = (p0.clone(), p0.clone());
            for _ in 0..3 {
                let grad = g.vec_f32(n, 1.0);
                oa.step(&mut pa, &grad, mask.runs(), 0.01);
                let mut gb = grad.clone();
                for (i, gi) in
                    gb.iter_mut().enumerate().skip(rows * cols)
                {
                    *gi = mask.value(i) * *gi;
                }
                ob.step(&mut pb, &gb, full.runs(), 0.01);
            }
            for i in 0..n {
                if i >= rows * cols && mask.value(i) == 0.0 {
                    assert_eq!(pa[i].to_bits(), p0[i].to_bits(),
                               "{}: frozen coord {i} moved",
                               oa.name());
                } else {
                    assert_eq!(pa[i].to_bits(), pb[i].to_bits(),
                               "{} coord {i}", oa.name());
                }
            }
        }
    });
}

#[test]
fn prop_sift_runs_step_bitwise_equals_dense_adamw_over_selection() {
    // SIFT's intersection walk (caller runs ∩ top-k selection) against
    // an independent dense emulation: replicate the deterministic t=0
    // selection externally (top-k of |g₁|; the refresh interval
    // exceeds the horizon so it never churns), gate the mask through
    // it, and drive the dense reference — same hp roster as SIFT's
    // default, so the match must be bitwise.
    check("sift runs == dense adamw over selection", 25, |g| {
        let n = g.usize_in(16, 200);
        let topk = *g.pick(&[0.1f64, 0.25, 1.0]);
        let mask = random_mask(g, n);
        let grads: Vec<Vec<f32>> =
            (0..4).map(|_| g.vec_f32(n, 1.0)).collect();
        let p0 = g.vec_f32(n, 1.0);
        let mut pa = p0.clone();
        let mut sift = SiftOptimizer::new(n, n, topk, 10);
        for gr in &grads {
            sift.step(&mut pa, gr, mask.runs(), 0.01);
        }
        // external replica of the t=0 selection (sift.rs::reselect)
        let kk = (((n as f64) * topk).ceil() as usize).min(n).max(1);
        let mut idx: Vec<usize> = (0..n).collect();
        idx.select_nth_unstable_by(kk - 1, |&a, &b| {
            grads[0][b].abs().partial_cmp(&grads[0][a].abs()).unwrap()
        });
        let mut eff = vec![0.0f32; n];
        for &i in &idx[..kk] {
            eff[i] = mask.value(i);
        }
        let mut pb = p0.clone();
        let mut dense = DenseAdamW::default_hp(n);
        for gr in &grads {
            dense.step(&mut pb, gr, &eff, 0.01);
        }
        for i in 0..n {
            assert_eq!(pa[i].to_bits(), pb[i].to_bits(), "coord {i}");
        }
        assert_eq!(sift.selected(), kk);
    });
}

#[test]
fn prop_step_sharded_bitwise_equals_serial_across_threads() {
    // Tentpole determinism contract: `step_sharded` must be bitwise
    // identical to the serial `step` for every optimizer at every
    // thread count — the shard partition decides *who* computes a
    // coordinate, never the arithmetic — including across a mid-run
    // mask refresh driven through `on_mask_refresh_sharded` (the
    // parallel state remap). Masks draw keep ratios from
    // {0.05, 0.25, 0.5, 1.0} and both structure shapes.
    check("step_sharded == step across threads", 16, |g| {
        let rows = g.usize_in(6, 10);
        let cols = g.usize_in(6, 10);
        let blen = g.usize_in(2, 8);
        let n = rows * cols + blen;
        let params = vec![
            ParamInfo {
                name: "w".into(),
                shape: vec![rows, cols],
                layer: "block_0".into(),
                offset: 0,
                len: rows * cols,
            },
            ParamInfo {
                name: "b".into(),
                shape: vec![blen],
                layer: "block_0".into(),
                offset: rows * cols,
                len: blen,
            },
        ];
        let mask_a = random_mask(g, n);
        let mask_b = random_mask(g, n);
        let grads: Vec<Vec<f32>> =
            (0..4).map(|_| g.vec_f32(n, 1.0)).collect();
        let p0 = g.vec_f32(n, 1.0);
        type Ctor<'a> = Box<dyn Fn() -> Box<dyn Optimizer> + 'a>;
        let ctors: Vec<(&str, Ctor)> = vec![
            ("adamw",
             Box::new(move || Box::new(MaskedAdamW::default_hp(n)))),
            ("sgdm",
             Box::new(move || {
                 Box::new(MaskedSgdm::new(n, 0.9, 1e-4, true))
             })),
            ("sgd", Box::new(|| Box::new(MaskedSgd))),
            ("golore",
             Box::new({
                 let params = params.clone();
                 move || Box::new(galore::golore(&params, n, 2, 2, 7))
             })),
            ("galore",
             Box::new({
                 let params = params.clone();
                 move || Box::new(galore::galore(&params, n, 2, 2, 7))
             })),
            ("sift",
             Box::new(move || {
                 Box::new(SiftOptimizer::new(n, n, 0.25, 10))
             })),
        ];
        for (name, ctor) in &ctors {
            // Serial reference trajectory: two steps, refresh, two more.
            let mut ps = p0.clone();
            let mut os = ctor();
            for gr in &grads[..2] {
                os.step(&mut ps, gr, mask_a.runs(), 0.01);
            }
            os.on_mask_refresh(mask_b.runs());
            for gr in &grads[2..] {
                os.step(&mut ps, gr, mask_b.runs(), 0.01);
            }
            for &th in &[1usize, 2, 4, 8] {
                let pool = ExecEngine::new(th);
                let mut pp = p0.clone();
                let mut op = ctor();
                for gr in &grads[..2] {
                    op.step_sharded(&mut pp, gr, mask_a.runs(), 0.01,
                                    &pool);
                }
                op.on_mask_refresh_sharded(mask_b.runs(), &pool);
                for gr in &grads[2..] {
                    op.step_sharded(&mut pp, gr, mask_b.runs(), 0.01,
                                    &pool);
                }
                for i in 0..n {
                    assert_eq!(ps[i].to_bits(), pp[i].to_bits(),
                               "{name} threads {th} coord {i}");
                }
            }
        }
    });
}

#[test]
fn prop_dense_bridge_matches_eager_expansion_through_splices() {
    // The lazy bridge contract: at any point in an arbitrary
    // set_segment splice sequence, dense_bridge() equals the vector an
    // always-resident eager implementation would hold, repeated reads
    // are cached (same pointer) until the next splice invalidates, and
    // every constructor round-trips through it.
    check("dense bridge == eager vector", 40, |g| {
        let n = g.usize_in(1, 120);
        let mut mask = Mask::zeros(n);
        let mut eager = vec![0.0f32; n];
        assert_eq!(mask.dense_bridge(), &eager[..]);
        for _ in 0..g.usize_in(1, 16) {
            let off = g.usize_in(0, n - 1);
            let len = g.usize_in(0, n - off);
            let scale = *g.pick(&[0.0f32, 1.0, 2.0, 4.0]);
            mask.set_segment(off, len, scale).unwrap();
            eager[off..off + len].fill(scale);
            assert_eq!(mask.dense_bridge(), &eager[..]);
            let p1 = mask.dense_bridge().as_ptr();
            assert_eq!(p1, mask.dense_bridge().as_ptr(), "cache miss");
        }
        // constructors round-trip through the bridge too
        let rebuilt = Mask::from_dense(eager.clone());
        assert_eq!(rebuilt.dense_bridge(), &eager[..]);
        assert_eq!(rebuilt.runs().runs(), mask.runs().runs());
        assert!(Mask::ones(n).dense_bridge().iter().all(|&v| v == 1.0));
    });
}

#[test]
fn prop_mask_splice_equals_dense_rebuild() {
    // The run splice behind set_segment must agree with a fresh dense
    // scan after any overwrite sequence — the invariant the cached
    // active count and every runs consumer lean on.
    check("mask splice == dense rebuild", 40, |g| {
        let n = g.usize_in(4, 120);
        let mut mask = Mask::zeros(n);
        for _ in 0..g.usize_in(1, 20) {
            let off = g.usize_in(0, n - 1);
            let len = g.usize_in(0, n - off);
            let scale = *g.pick(&[0.0f32, 0.0, 1.0, 2.0, 4.0]);
            mask.set_segment(off, len, scale).unwrap();
            let rescan = MaskRuns::from_dense(mask.dense_bridge());
            assert_eq!(mask.runs().runs(), rescan.runs());
            assert_eq!(mask.active_count(), rescan.active_count());
        }
    });
}

// -------------------------------------------------------------------------
// Crash-safe job journal: replay consistency under arbitrary
// interleavings and torn tails (docs/durability.md)
// -------------------------------------------------------------------------

fn journal_spec(seed: u64) -> JobSpec {
    let mut cfg = RunConfig::default();
    cfg.seed = seed;
    JobSpec {
        kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 2 },
        cfg,
    }
}

fn journal_admit(g: &mut Gen, seq: u64) -> Record {
    Record::Admit {
        seq,
        priority: g.usize_in(0, 3) as i32,
        client: g.bool().then(|| format!("c{}", g.usize_in(0, 2))),
        spec: journal_spec(seq),
    }
}

fn journal_done(g: &mut Gen, seq: u64) -> Record {
    let status = if g.bool() {
        JobStatus::Done(JobOutcome {
            final_metric: seq as f64 + 0.5,
            tail_loss: 0.25,
            steps: 3,
            train_secs: 1.0,
            loss_series: vec![(0, 2.0)],
            eval_series: vec![(1, 1.0, 50.0)],
        })
    } else {
        JobStatus::Failed(format!("boom {seq}"))
    };
    Record::Done {
        seq,
        status,
        from_cache: g.bool(),
        secs: 0.5,
        spec: journal_spec(seq),
    }
}

/// A random but *causally plausible* record interleaving: seqs are
/// admitted in order, leases/renewals name live seqs, each seq finishes
/// at most once — plus the one reordering the hub really produces
/// (an ultra-fast job's `done` landing before its `admit`, which is
/// fsynced outside the dispatch lock).
fn journal_history(g: &mut Gen) -> Vec<Record> {
    let mut recs = Vec::new();
    let mut next_seq = 0u64;
    let mut live: Vec<u64> = Vec::new();
    for _ in 0..g.usize_in(1, 24) {
        match g.usize_in(0, 6) {
            2 if !live.is_empty() => {
                let seq = *g.pick(&live);
                recs.push(Record::Lease { seq, worker: "w-0".into() });
            }
            3 if !live.is_empty() => {
                let seq = *g.pick(&live);
                recs.push(Record::Renew { seq, worker: "w-0".into() });
            }
            4 if !live.is_empty() => {
                let i = g.usize_in(0, live.len() - 1);
                let seq = live.remove(i);
                recs.push(journal_done(g, seq));
            }
            5 if !live.is_empty() => {
                let i = g.usize_in(0, live.len() - 1);
                recs.push(Record::Cancel { seq: live.remove(i) });
            }
            6 => {
                // done-before-admit reordering (cached instant job)
                let seq = next_seq;
                next_seq += 1;
                recs.push(journal_done(g, seq));
                recs.push(journal_admit(g, seq));
            }
            _ => {
                let seq = next_seq;
                next_seq += 1;
                recs.push(journal_admit(g, seq));
                live.push(seq);
            }
        }
    }
    recs
}

#[test]
fn prop_journal_replay_is_consistent_under_any_torn_tail() {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CASE: AtomicUsize = AtomicUsize::new(0);
    check("journal replay consistency", 30, |g| {
        let recs = journal_history(g);
        let lines: Vec<String> =
            recs.iter().map(Record::encode_line).collect();
        let full: Vec<u8> = lines.concat().into_bytes();
        let tail_len = lines.last().unwrap().len();
        let path = std::env::temp_dir().join(format!(
            "omgd-prop-journal-{}-{}.log",
            std::process::id(),
            CASE.fetch_add(1, Ordering::Relaxed),
        ));
        // Truncate at every byte boundary inside (and around) the final
        // record — the only damage an fsynced append can leave.
        for cut in (full.len() - tail_len)..=full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let rep = journal::replay(&path).unwrap();
            let kept: &[Record] = if cut == full.len() {
                &recs
            } else {
                &recs[..recs.len() - 1]
            };
            assert_eq!(rep.replayed, kept.len(), "cut at {cut}");
            // Model the kept prefix directly: admitted minus finished.
            let mut admitted = HashSet::new();
            let mut done = HashSet::new();
            let mut gone = HashSet::new();
            let mut max_seq = None::<u64>;
            for r in kept {
                let seq = match r {
                    Record::Meta { .. } => continue,
                    Record::Admit { seq, .. } => {
                        admitted.insert(*seq);
                        *seq
                    }
                    Record::Done { seq, .. } => {
                        done.insert(*seq);
                        *seq
                    }
                    Record::Cancel { seq } => {
                        gone.insert(*seq);
                        *seq
                    }
                    Record::Lease { seq, .. }
                    | Record::Renew { seq, .. } => *seq,
                };
                max_seq = Some(max_seq.map_or(seq, |m| m.max(seq)));
            }
            // Monotone seq counter: strictly above everything replayed.
            assert_eq!(
                rep.next_seq,
                max_seq.map_or(0, |m| m + 1),
                "cut at {cut}"
            );
            // No lost completions: every fully-recorded done survives.
            let replayed_done: HashSet<u64> =
                rep.completed.iter().map(|r| r.seq).collect();
            assert_eq!(replayed_done, done, "cut at {cut}");
            // No double dispatch: a seq is pending XOR finished, and
            // pending is exactly admitted − done − cancelled.
            let pending: Vec<u64> =
                rep.pending.iter().map(|p| p.seq).collect();
            let pending_set: HashSet<u64> =
                pending.iter().copied().collect();
            assert_eq!(pending_set.len(), pending.len(), "dup pending");
            assert!(
                pending_set.is_disjoint(&replayed_done),
                "cut at {cut}: a seq is both pending and completed"
            );
            let want: HashSet<u64> = admitted
                .iter()
                .copied()
                .filter(|s| !done.contains(s) && !gone.contains(s))
                .collect();
            assert_eq!(pending_set, want, "cut at {cut}");
            // Replay hands jobs back in seq order.
            assert!(
                pending.windows(2).all(|w| w[0] < w[1]),
                "pending out of order"
            );
        }
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn maskset_runs_coverage_matches_section_5_2_worked_example() {
    // The §5.2 worked example in runs form: d = 6 (embed, 4 middle
    // layers, head), M = 4, S⁽ʲ⁾ = (1, …, 4 at middle j, …, 1)ᵀ —
    // eq. (3) verified entirely over the segment-run views.
    let mut masks = Vec::new();
    for j in 0..4 {
        let mut m = Mask::zeros(6);
        m.set_segment(0, 1, 1.0).unwrap();
        m.set_segment(1 + j, 1, 4.0).unwrap();
        m.set_segment(5, 1, 1.0).unwrap();
        // always three runs: embed@1, the selected middle@4, head@1 —
        // adjacency never merges runs of different scale
        assert_eq!(m.runs().runs().len(), 3, "mask {j}");
        masks.push(m);
    }
    let set = MaskSet { masks };
    let c = set.coverage_scalar(6).expect("eq. (3) holds over runs");
    assert!((c - 4.0).abs() < 1e-6, "c={c}");
    // each mask keeps 3 of 6 coordinates — the compact state the
    // engine would hold is half the dense footprint
    for m in &set.masks {
        assert_eq!(m.active_count(), 3);
        assert!((m.keep_ratio() - 0.5).abs() < 1e-12);
    }
}
