//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These exercise the full request path the production binary uses:
//! manifest → compile HLO → execute train/eval/update → trainer loops.
//! They require `make artifacts` (the `gpt-nano` / `mlp-glue` / `linreg`
//! test configs); each test skips with a message if artifacts are absent
//! so `cargo test` stays green on a fresh checkout.

use omgd::config::{Method, OptFamily, RunConfig};
use omgd::coordinator::Mask;
use omgd::data::{ClassTask, GLUE_LIKE_TASKS};
use omgd::experiments::{load_bundle, load_bundle_sgdm, pretrain_corpus};
use omgd::manifest::Manifest;
use omgd::optim::{MaskedAdamW, MaskedSgdm, Optimizer};
use omgd::rng::Rng;
use omgd::runtime::{artifacts_dir, Runtime, RunsScratch};
use omgd::train::{train_classifier, train_lm, MethodEngine};

fn have(model: &str) -> bool {
    let ok = artifacts_dir(None).join(format!("{model}.json")).exists();
    if !ok {
        eprintln!("SKIP: artifacts for {model} missing (run make artifacts)");
    }
    ok
}

fn rt() -> Runtime {
    Runtime::cpu().expect("pjrt cpu client")
}

// -------------------------------------------------------------------------
// Runtime plumbing
// -------------------------------------------------------------------------

#[test]
fn linreg_artifact_matches_closed_form() {
    if !have("linreg") {
        return;
    }
    let rt = rt();
    let dir = artifacts_dir(None);
    let exe = rt.load(&dir.join("linreg.grad.hlo.txt")).unwrap();
    let mut rng = Rng::seed_from_u64(0);
    for _ in 0..10 {
        let theta: Vec<f32> = (0..10).map(|_| rng.normal32()).collect();
        let x: Vec<f32> = (0..10).map(|_| rng.normal32()).collect();
        let y = rng.normal32();
        let g = rt.linreg_grad(&exe, &theta, &x, y).unwrap();
        let resid: f32 =
            x.iter().zip(&theta).map(|(a, b)| a * b).sum::<f32>() - y;
        for i in 0..10 {
            let want = 2.0 * resid * x[i];
            assert!((g[i] - want).abs() < 1e-4 * (1.0 + want.abs()),
                    "coord {i}: {} vs {want}", g[i]);
        }
    }
}

#[test]
fn manifest_matches_artifacts_on_disk() {
    if !have("gpt-nano") {
        return;
    }
    let dir = artifacts_dir(None);
    let man = Manifest::load(&dir, "gpt-nano").unwrap();
    assert_eq!(man.kind, "gpt");
    man.check().unwrap();
    let init = man.load_init().unwrap();
    assert_eq!(init.len(), man.padded_len);
    // padding tail of init is zero
    assert!(init[man.total_len..].iter().all(|&x| x == 0.0));
}

// -------------------------------------------------------------------------
// HLO kernel ⇄ native optimizer cross-checks (the core numeric contract)
// -------------------------------------------------------------------------

#[test]
fn hlo_adamw_update_matches_native_mirror() {
    if !have("mlp-glue") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle(&rt, "mlp-glue").unwrap();
    let n = bundle.padded_len();
    let mut rng = Rng::seed_from_u64(1);

    let p0: Vec<f32> = (0..n).map(|_| rng.normal32() * 0.1).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
    let mut dense = vec![0.0f32; n];
    for d in dense.iter_mut().take(bundle.man.total_len) {
        if rng.f64() < 0.5 {
            *d = 2.0;
        }
    }
    let mask = Mask::from_dense(dense);

    // HLO path (three steps to exercise state accumulation).
    let (mut ph, mut mh, mut vh) =
        (p0.clone(), vec![0.0f32; n], vec![0.0f32; n]);
    // Native path.
    let mut pn = p0.clone();
    let mut nat = MaskedAdamW::new(n, 0.9, 0.999, 1e-8, 0.01);
    let mut scratch = RunsScratch::new();

    for step in 1..=3u64 {
        let bc1 = 1.0 - 0.9f32.powi(step as i32);
        let bc2 = 1.0 - 0.999f32.powi(step as i32);
        let hp = [1e-3, 0.9, 0.999, 1e-8, 0.01, bc1, bc2, 0.0];
        bundle
            .adamw_update_runs(&mut ph, &g, &mask.runs().descriptors(),
                               &mut mh, &mut vh, &hp, &mut scratch)
            .unwrap();
        nat.step(&mut pn, &g, mask.runs(), 1e-3);
    }
    let max_dp = ph
        .iter()
        .zip(&pn)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dp < 1e-5, "HLO vs native AdamW diverged: {max_dp}");
    // moments must match too: the native optimizer holds state only
    // for the active region; frozen coords must be zero on both sides
    let max_dm = (0..n)
        .map(|i| {
            let nm = nat.moment_at(i).map(|(m, _)| m).unwrap_or(0.0);
            (mh[i] - nm).abs()
        })
        .fold(0.0f32, f32::max);
    assert!(max_dm < 1e-5, "moment mismatch {max_dm}");
    assert_eq!(nat.resident(), mask.active_count());
}

#[test]
fn hlo_sgdm_update_matches_native_mirror() {
    if !have("mlp-glue") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle_sgdm(&rt, "mlp-glue").unwrap();
    let n = bundle.padded_len();
    let mut rng = Rng::seed_from_u64(2);

    let p0: Vec<f32> = (0..n).map(|_| rng.normal32() * 0.1).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
    let mut mask = Mask::zeros(n);
    mask.set_segment(0, bundle.man.total_len, 1.0).unwrap();

    let (mut ph, mut bh) = (p0.clone(), vec![0.0f32; n]);
    let mut pn = p0.clone();
    let mut nat = MaskedSgdm::new(n, 0.9, 1e-4, true);
    let hp = [0.05f32, 0.9, 1e-4, 1.0];
    let mut scratch = RunsScratch::new();
    for _ in 0..3 {
        bundle
            .sgdm_update_runs(&mut ph, &g, &mask.runs().descriptors(),
                              &mut bh, &hp, &mut scratch)
            .unwrap();
        nat.step(&mut pn, &g, mask.runs(), 0.05);
    }
    let max_dp = ph
        .iter()
        .zip(&pn)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dp < 1e-5, "HLO vs native SGDM diverged: {max_dp}");
}

#[test]
fn frozen_coordinates_are_bit_identical_through_hlo() {
    if !have("mlp-glue") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle(&rt, "mlp-glue").unwrap();
    let n = bundle.padded_len();
    let mut rng = Rng::seed_from_u64(3);
    let p0: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
    let mut mask = Mask::zeros(n);
    mask.set_segment(0, n / 2, 4.0).unwrap();
    let (mut p, mut m, mut v) =
        (p0.clone(), vec![0.0f32; n], vec![0.0f32; n]);
    let hp = [1e-2f32, 0.9, 0.999, 1e-8, 0.01, 0.1, 0.001, 0.0];
    bundle
        .adamw_update(&mut p, &g, mask.dense_bridge(), &mut m, &mut v,
                      &hp)
        .unwrap();
    // frozen half: bit-identical params, zero moments
    assert_eq!(&p[n / 2..], &p0[n / 2..]);
    assert!(m[n / 2..].iter().all(|&x| x == 0.0));
    // active half: every coordinate moved
    assert!(p[..n / 2].iter().zip(&p0[..n / 2]).all(|(a, b)| a != b));
}

#[test]
fn hlo_runs_descriptor_path_matches_dense_fallback_bitwise() {
    // Tentpole contract: the runs-descriptor entry expands into exactly
    // the multiplier the dense fallback is handed, so the same kernel
    // sees identical operands — outputs must match to the bit, across
    // mask changes (scratch-cache invalidation included).
    if !have("mlp-glue") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle(&rt, "mlp-glue").unwrap();
    let n = bundle.padded_len();
    let mut rng = Rng::seed_from_u64(4);
    let p0: Vec<f32> = (0..n).map(|_| rng.normal32() * 0.1).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
    let mut mask = Mask::zeros(n);
    mask.set_segment(0, n / 2, 2.0).unwrap();
    let (mut pr, mut mr, mut vr) =
        (p0.clone(), vec![0.0f32; n], vec![0.0f32; n]);
    let (mut pd, mut md, mut vd) =
        (p0, vec![0.0f32; n], vec![0.0f32; n]);
    let mut scratch = RunsScratch::new();
    for step in 1..=4u64 {
        if step == 3 {
            // mid-sequence mask change: the descriptor cache must
            // re-expand, not serve the stale multiplier
            mask.set_segment(0, n / 4, 0.0).unwrap();
            mask.set_segment(n / 2, n / 4, 0.5).unwrap();
        }
        let bc1 = 1.0 - 0.9f32.powi(step as i32);
        let bc2 = 1.0 - 0.999f32.powi(step as i32);
        let hp = [1e-3, 0.9, 0.999, 1e-8, 0.01, bc1, bc2, 0.0];
        bundle
            .adamw_update_runs(&mut pr, &g, &mask.runs().descriptors(),
                               &mut mr, &mut vr, &hp, &mut scratch)
            .unwrap();
        bundle
            .adamw_update(&mut pd, &g, mask.dense_bridge(), &mut md,
                          &mut vd, &hp)
            .unwrap();
    }
    assert!(pr.iter().zip(&pd).all(|(a, b)| a.to_bits() == b.to_bits()));
    assert!(mr.iter().zip(&md).all(|(a, b)| a.to_bits() == b.to_bits()));
    assert!(vr.iter().zip(&vd).all(|(a, b)| a.to_bits() == b.to_bits()));
}

// -------------------------------------------------------------------------
// Trainer end-to-end (short runs)
// -------------------------------------------------------------------------

fn quick_cfg(method: Method, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.method = method;
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.opt.lr = 2e-3;
    cfg.mask.gamma = 4;
    cfg.mask.period = 1;
    cfg.seed = 9;
    cfg
}

#[test]
fn classifier_training_reduces_loss_all_methods() {
    if !have("mlp-glue") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle(&rt, "mlp-glue").unwrap();
    let task = ClassTask::from_spec(&GLUE_LIKE_TASKS[4], // SST2-like, easy
                                    bundle.man.data.d_in,
                                    bundle.man.data.n_class);
    for method in [Method::Full, Method::LisaWor, Method::IidMask,
                   Method::WorMask, Method::Sift] {
        let cfg = quick_cfg(method, 60);
        let out = train_classifier(&bundle, &cfg, &task).unwrap();
        let head: f64 = out.loss_series[..10].iter()
            .map(|&(_, l)| l).sum::<f64>() / 10.0;
        let tail = out.tail_loss(10);
        assert!(
            tail < head,
            "{}: loss did not fall ({head:.4} → {tail:.4})",
            method.name()
        );
        assert!(out.final_metric > 20.0,
                "{}: degenerate accuracy {}", method.name(),
                out.final_metric);
    }
}

#[test]
fn lm_training_reduces_loss() {
    if !have("gpt-nano") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle(&rt, "gpt-nano").unwrap();
    let corpus = pretrain_corpus(&bundle, 60);
    let mut cfg = quick_cfg(Method::LisaWor, 60);
    cfg.mask.gamma = 1;
    cfg.mask.period = 10;
    cfg.opt.lr = 3e-3;
    let out = train_lm(&bundle, &cfg, &corpus).unwrap();
    let first = out.loss_series[0].1;
    let tail = out.tail_loss(10);
    assert!(tail < first - 0.2,
            "LM loss did not fall: {first:.3} → {tail:.3}");
    // initial loss ≈ ln(vocab)
    assert!((first - (bundle.man.data.vocab as f64).ln()).abs() < 1.0);
}

#[test]
fn deterministic_given_seed() {
    if !have("mlp-glue") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle(&rt, "mlp-glue").unwrap();
    let task = ClassTask::from_spec(&GLUE_LIKE_TASKS[2],
                                    bundle.man.data.d_in,
                                    bundle.man.data.n_class);
    let cfg = quick_cfg(Method::LisaWor, 20);
    let a = train_classifier(&bundle, &cfg, &task).unwrap();
    let b = train_classifier(&bundle, &cfg, &task).unwrap();
    assert_eq!(a.loss_series, b.loss_series, "training not deterministic");
    assert_eq!(a.final_metric, b.final_metric);
}

#[test]
fn sgdm_family_trains_through_hlo() {
    if !have("mlp-img") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle_sgdm(&rt, "mlp-img").unwrap();
    let task = ClassTask::gaussian_blobs(
        "img", bundle.man.data.d_in, bundle.man.data.n_class, 400, 100,
        0.6, 12,
    );
    for method in [Method::Full, Method::IidMask, Method::WorMask] {
        let mut cfg = quick_cfg(method, 40);
        cfg.opt.family = OptFamily::Sgdm;
        cfg.opt.lr = 0.05;
        let out = train_classifier(&bundle, &cfg, &task).unwrap();
        assert!(out.tail_loss(10) < out.loss_series[0].1,
                "{} failed to descend", method.name());
    }
}

#[test]
fn engine_state_bytes_ordering_through_real_manifest() {
    if !have("mlp-glue") {
        return;
    }
    let rt = rt();
    let bundle = load_bundle(&rt, "mlp-glue").unwrap();
    let mut rng = Rng::seed_from_u64(0);
    let mut mk = |method| {
        let cfg = quick_cfg(method, 1);
        let mut e = MethodEngine::new(&bundle.man, &cfg, &mut rng).unwrap();
        e.on_period(&mut rng).unwrap();
        e.state_bytes()
    };
    let full = mk(Method::Full);
    let lisa = mk(Method::LisaWor);
    let golore = mk(Method::Golore);
    assert!(lisa < full, "LISA {lisa} !< full {full}");
    assert!(golore < full, "GoLore {golore} !< full {full}");
}
