//! Integration tests for the HTTP gateway (`jobs::net`) over real
//! loopback sockets, with stub workers — no artifacts, no PJRT.
//!
//! Under test: the acceptance criteria of the gateway — ≥2 concurrent
//! clients share one worker pool with results routed back to the right
//! connection (matched on `seq`), a saturated queue answers `429` +
//! `Retry-After`, and `POST /shutdown` drains gracefully.

use omgd::jobs::{
    run_gateway, GatewayStats, JobOutcome, JobSpec, ListenOptions,
};
use omgd::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn stub_outcome(spec: &JobSpec) -> JobOutcome {
    JobOutcome {
        final_metric: spec.cfg.seed as f64 + 0.5,
        tail_loss: 0.25,
        steps: 2,
        train_secs: 0.0,
        loss_series: vec![(0, 1.0)],
        eval_series: vec![],
    }
}

fn request_line(seed: u64) -> String {
    format!(
        "{{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":{seed},\
         \"epochs\":1}}\n"
    )
}

/// Start a gateway on a free loopback port with `workers` stub workers
/// that sleep ~10ms per job (so concurrent clients really overlap).
fn start_gateway(
    workers: usize,
    lopts: ListenOptions,
) -> (SocketAddr, std::thread::JoinHandle<GatewayStats>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        run_gateway(listener, workers, &lopts, None, |_wid| {
            |spec: &JobSpec| {
                std::thread::sleep(Duration::from_millis(10));
                Ok((stub_outcome(spec), false))
            }
        })
        .unwrap()
    });
    (addr, handle)
}

/// One HTTP/1.1 request; returns (status, headers, body). The body is
/// read to EOF (every `Connection: close` gateway response).
fn http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, BTreeMap<String, String>, String) {
    http_hdr(addr, method, path, &[], body)
}

/// [`http`] with extra request headers (e.g. `X-OMGD-Client`).
fn http_hdr(
    addr: SocketAddr,
    method: &str,
    path: &str,
    extra: &[(&str, &str)],
    body: &str,
) -> (u16, BTreeMap<String, String>, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let extra_hdrs: String = extra
        .iter()
        .map(|(k, v)| format!("{k}: {v}\r\n"))
        .collect();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: omgd-test\r\n\
         Content-Length: {}\r\n{extra_hdrs}Connection: close\r\n\r\n\
         {body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut r = BufReader::new(stream);
    let mut status_line = String::new();
    r.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .unwrap();
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers
                .insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    let mut body = String::new();
    r.read_to_string(&mut body).unwrap();
    (status, headers, body)
}

/// One request/response round on an already-open keep-alive
/// connection. The response must be `Content-Length`-framed (every
/// non-stream gateway response is); asserts the gateway answered
/// `Connection: keep-alive` so the socket stays usable.
fn keep_alive_round(
    r: &mut BufReader<TcpStream>,
    method: &str,
    path: &str,
    extra: &[(&str, &str)],
    body: &str,
) -> (u16, BTreeMap<String, String>, String) {
    let extra_hdrs: String = extra
        .iter()
        .map(|(k, v)| format!("{k}: {v}\r\n"))
        .collect();
    {
        let mut w = r.get_ref();
        write!(
            w,
            "{method} {path} HTTP/1.1\r\nHost: omgd-test\r\n\
             Content-Length: {}\r\n{extra_hdrs}\
             Connection: keep-alive\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        w.flush().unwrap();
    }
    let mut status_line = String::new();
    r.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .unwrap();
    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers
                .insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }
    assert_eq!(
        headers.get("connection").map(String::as_str),
        Some("keep-alive"),
        "{method} {path} must keep the connection alive"
    );
    let len: usize = headers
        .get("content-length")
        .expect("keep-alive responses are length-framed")
        .parse()
        .unwrap();
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).unwrap();
    (status, headers, String::from_utf8(buf).unwrap())
}

/// Parse a streamed NDJSON `/jobs` response into (acks, results).
fn split_stream(body: &str) -> (Vec<Json>, Vec<Json>) {
    let lines: Vec<Json> = body
        .lines()
        .map(|l| Json::parse(l).expect("NDJSON line"))
        .collect();
    let acks = lines
        .iter()
        .filter(|j| j.get("accepted").is_some())
        .cloned()
        .collect();
    let results = lines
        .iter()
        .filter(|j| j.get("status").is_some())
        .cloned()
        .collect();
    (acks, results)
}

#[test]
fn two_concurrent_clients_share_one_pool_without_crosstalk() {
    let (addr, gateway) = start_gateway(2, ListenOptions::default());

    let post = |seeds: std::ops::Range<u64>| {
        let body: String = seeds.clone().map(request_line).collect();
        let (status, headers, text) = http(addr, "POST", "/jobs", &body);
        assert_eq!(status, 200);
        assert_eq!(
            headers.get("content-type").map(String::as_str),
            Some("application/x-ndjson")
        );
        let (acks, results) = split_stream(&text);
        assert_eq!(acks.len(), seeds.clone().count());
        assert_eq!(results.len(), acks.len());
        // Acks arrive in request order: ack i ↔ the i-th posted seed.
        let seq_to_seed: BTreeMap<u64, u64> = acks
            .iter()
            .zip(seeds)
            .map(|(a, seed)| {
                (a.at("accepted").as_f64().unwrap() as u64, seed)
            })
            .collect();
        // Every streamed result belongs to THIS client and carries the
        // outcome of its own seed (metric = seed + 0.5).
        for r in &results {
            let seq = r.at("seq").as_f64().unwrap() as u64;
            let seed = *seq_to_seed
                .get(&seq)
                .expect("result seq matches one of this client's acks");
            assert_eq!(r.at("status").as_str(), Some("done"));
            assert_eq!(
                r.at("final_metric").as_f64().unwrap(),
                seed as f64 + 0.5
            );
        }
        seq_to_seed.keys().copied().collect::<BTreeSet<u64>>()
    };

    let (seqs_a, seqs_b) = std::thread::scope(|s| {
        let a = s.spawn(|| post(0..4));
        let b = s.spawn(|| post(10..14));
        (a.join().unwrap(), b.join().unwrap())
    });
    // One shared queue: the global seq namespace never collides.
    assert!(seqs_a.is_disjoint(&seqs_b));
    assert_eq!(seqs_a.len() + seqs_b.len(), 8);

    let (status, _, body) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    assert!(body.contains("draining"));
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.accepted, 8);
    assert_eq!(stats.jobs.done, 8);
    assert_eq!(stats.jobs.failed, 0);
    assert!(stats.connections >= 3, "2 × POST /jobs + shutdown");
}

#[test]
fn saturated_queue_returns_429_with_retry_after() {
    // 1 worker, queue of 1: park the worker, fill the queue, then a new
    // POST /jobs must bounce with 429 instead of queueing.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (started_tx, started_rx) = mpsc::channel::<()>();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let started_tx = Arc::new(Mutex::new(started_tx));
    let release_rx = Arc::new(Mutex::new(release_rx));
    let lopts = ListenOptions {
        queue_capacity: 1,
        ..ListenOptions::default()
    };
    let gateway = std::thread::spawn(move || {
        run_gateway(listener, 1, &lopts, None, |_wid| {
            let started = Arc::clone(&started_tx);
            let release = Arc::clone(&release_rx);
            move |spec: &JobSpec| {
                started.lock().unwrap().send(()).ok();
                release.lock().unwrap().recv().ok();
                Ok((stub_outcome(spec), false))
            }
        })
        .unwrap()
    });

    // Client A: two jobs. The worker parks on job 1; job 2 fills the
    // bounded queue.
    let blocked_client = std::thread::spawn(move || {
        let body: String = (0..2).map(request_line).collect();
        http(addr, "POST", "/jobs", &body)
    });
    started_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("worker picked up job 1");
    // Wait until job 2 is actually queued (queue_len goes to 1).
    let mut saturated = false;
    for _ in 0..400 {
        let (status, _, body) = http(addr, "GET", "/healthz", "");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        if j.at("queue_len").as_usize() == Some(1) {
            saturated = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(saturated, "queue never filled");

    let (status, headers, body) =
        http(addr, "POST", "/jobs", &request_line(7));
    assert_eq!(status, 429);
    assert_eq!(headers.get("retry-after").map(String::as_str), Some("1"));
    assert!(body.contains("queue is full"));

    // Un-park the worker; client A's stream completes normally.
    release_tx.send(()).unwrap();
    release_tx.send(()).unwrap();
    let (status, _, text) = blocked_client.join().unwrap();
    assert_eq!(status, 200);
    let (acks, results) = split_stream(&text);
    assert_eq!((acks.len(), results.len()), (2, 2));

    let (status, _, _) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.throttled, 1);
    assert_eq!(stats.jobs.done, 2);
}

/// Satellite regression: a prefix-matching but malformed `/work/` path
/// must answer a 400 error shape (it used to risk panicking the
/// connection thread via an unchecked parse), and wrong methods on
/// worker paths stay 405.
#[test]
fn malformed_work_paths_answer_400_not_panic() {
    let (addr, gateway) = start_gateway(1, ListenOptions::default());

    for path in [
        "/work/x/result",
        "/work/7/steal",
        "/work//renew",
        "/work/99999999999999999999999999/result", // u64 overflow
    ] {
        let (status, _, body) = http(addr, "POST", path, "{}");
        assert_eq!(status, 400, "{path} must 400: {body}");
        let j = Json::parse(&body).unwrap();
        assert!(
            j.at("error").as_str().unwrap().contains("malformed"),
            "{path}: {body}"
        );
    }
    let (status, _, _) = http(addr, "GET", "/work/7/renew", "");
    assert_eq!(status, 405, "wrong method on a well-formed work path");
    // The gateway survived all of it.
    let (status, _, _) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);

    let (status, _, _) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    gateway.join().unwrap();
}

/// Tentpole: one keep-alive connection carries several
/// request/response rounds — including a 429 — and the `POST /jobs`
/// stream arrives chunked so the socket survives it too.
#[test]
fn keep_alive_connection_carries_multiple_rounds_including_429() {
    // 1 worker, queue of 1: park the worker, fill the queue, then
    // exercise a keep-alive connection against the saturated gateway.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (started_tx, started_rx) = mpsc::channel::<()>();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let started_tx = Arc::new(Mutex::new(started_tx));
    let release_rx = Arc::new(Mutex::new(release_rx));
    let lopts = ListenOptions {
        queue_capacity: 1,
        ..ListenOptions::default()
    };
    let gateway = std::thread::spawn(move || {
        run_gateway(listener, 1, &lopts, None, |_wid| {
            let started = Arc::clone(&started_tx);
            let release = Arc::clone(&release_rx);
            move |spec: &JobSpec| {
                started.lock().unwrap().send(()).ok();
                release.lock().unwrap().recv().ok();
                Ok((stub_outcome(spec), false))
            }
        })
        .unwrap()
    });

    let blocked_client = std::thread::spawn(move || {
        let body: String = (0..2).map(request_line).collect();
        http(addr, "POST", "/jobs", &body)
    });
    started_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("worker picked up job 1");
    let mut saturated = false;
    for _ in 0..400 {
        let (_, _, body) = http(addr, "GET", "/healthz", "");
        if Json::parse(&body).unwrap().at("queue_len").as_usize()
            == Some(1)
        {
            saturated = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(saturated, "queue never filled");

    // One socket, four rounds: healthz → 429 on /jobs → stats → 404.
    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut conn = BufReader::new(stream);
    let (status, _, body) =
        keep_alive_round(&mut conn, "GET", "/healthz", &[], "");
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\":true"));
    let (status, headers, body) = keep_alive_round(
        &mut conn,
        "POST",
        "/jobs",
        &[],
        &request_line(7),
    );
    assert_eq!(status, 429, "saturated queue still throttles: {body}");
    assert_eq!(headers.get("retry-after").map(String::as_str), Some("1"));
    let (status, _, body) =
        keep_alive_round(&mut conn, "GET", "/stats", &[], "");
    assert_eq!(status, 200);
    assert!(body.contains("\"throttled_429\":1"), "{body}");
    let (status, _, _) =
        keep_alive_round(&mut conn, "GET", "/nope", &[], "");
    assert_eq!(status, 404, "even errors ride the same connection");

    // A keep-alive POST /jobs streams chunked and leaves the socket
    // usable: submit one job (queue has room once the worker moves).
    release_tx.send(()).unwrap(); // finish job 1; worker takes job 2
    release_tx.send(()).unwrap(); // finish job 2
    let (status, _, text) = blocked_client.join().unwrap();
    assert_eq!(status, 200);
    let (acks, results) = split_stream(&text);
    assert_eq!((acks.len(), results.len()), (2, 2));
    {
        let mut w = conn.get_ref();
        let body = request_line(9);
        write!(
            w,
            "POST /jobs HTTP/1.1\r\nHost: omgd-test\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        w.flush().unwrap();
    }
    release_tx.send(()).unwrap(); // let job 3 run
    let mut status_line = String::new();
    conn.read_line(&mut status_line).unwrap();
    assert!(status_line.starts_with("HTTP/1.1 200"), "{status_line}");
    let mut chunked = false;
    loop {
        let mut h = String::new();
        conn.read_line(&mut h).unwrap();
        let h = h.trim_end().to_ascii_lowercase();
        if h.is_empty() {
            break;
        }
        if h == "transfer-encoding: chunked" {
            chunked = true;
        }
    }
    assert!(chunked, "keep-alive /jobs stream must be chunked");
    let mut cr = omgd::jobs::net::ChunkedReader::new(&mut conn);
    let mut session = String::new();
    cr.read_to_string(&mut session).unwrap();
    let (acks, results) = split_stream(&session);
    assert_eq!((acks.len(), results.len()), (1, 1));
    // …and a fifth round on the very same socket still works.
    let (status, _, _) =
        keep_alive_round(&mut conn, "GET", "/healthz", &[], "");
    assert_eq!(status, 200);
    drop(conn);

    let (status, _, _) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 3);
    assert_eq!(stats.throttled, 1);
}

/// Tentpole: `--client-quota` fairness — a token at its in-flight cap
/// gets the 429 + Retry-After shape while other tokens sail through.
#[test]
fn client_quota_throttles_greedy_token_but_not_siblings() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let release_rx = Arc::new(Mutex::new(release_rx));
    let lopts = ListenOptions {
        client_quota: 2,
        queue_capacity: 8,
        ..ListenOptions::default()
    };
    let gateway = std::thread::spawn(move || {
        run_gateway(listener, 1, &lopts, None, |_wid| {
            let release = Arc::clone(&release_rx);
            move |spec: &JobSpec| {
                release.lock().unwrap().recv().ok();
                Ok((stub_outcome(spec), false))
            }
        })
        .unwrap()
    });

    // Greedy client: one session, 2 jobs — exactly at quota while the
    // parked worker sits on job 1.
    let greedy = std::thread::spawn(move || {
        let body: String = (0..2).map(request_line).collect();
        http_hdr(
            addr,
            "POST",
            "/jobs",
            &[("X-OMGD-Client", "alpha")],
            &body,
        )
    });
    // Deterministic signal: the hub's client ledger shows alpha at 2.
    let mut at_quota = false;
    for _ in 0..400 {
        let (_, _, body) = http(addr, "GET", "/stats", "");
        let j = Json::parse(&body).unwrap();
        if j.at("clients").get("alpha").and_then(Json::as_usize)
            == Some(2)
        {
            at_quota = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(at_quota, "alpha never reached its quota");

    // A second alpha session bounces with the 429 + Retry-After shape…
    let (status, headers, body) = http_hdr(
        addr,
        "POST",
        "/jobs",
        &[("X-OMGD-Client", "alpha")],
        &request_line(7),
    );
    assert_eq!(status, 429, "over-quota token must bounce: {body}");
    assert_eq!(headers.get("retry-after").map(String::as_str), Some("1"));
    assert!(body.contains("quota"), "{body}");

    // …while a different token is admitted into the same queue.
    let beta = std::thread::spawn(move || {
        http_hdr(
            addr,
            "POST",
            "/jobs",
            &[("X-OMGD-Client", "beta")],
            &request_line(20),
        )
    });
    // Unpark: 2 alpha jobs + 1 beta job drain.
    for _ in 0..3 {
        release_tx.send(()).unwrap();
    }
    let (status, _, text) = greedy.join().unwrap();
    assert_eq!(status, 200);
    let (acks, results) = split_stream(&text);
    assert_eq!((acks.len(), results.len()), (2, 2));
    let (status, _, text) = beta.join().unwrap();
    assert_eq!(status, 200, "beta was never quota-throttled");
    let (acks, results) = split_stream(&text);
    assert_eq!((acks.len(), results.len()), (1, 1));

    let (status, _, _) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.quota_throttled, 1);
    assert_eq!(stats.jobs.done, 3);
    assert_eq!(stats.throttled, 0, "queue itself never saturated");
}

#[test]
fn control_endpoints_and_error_shapes() {
    let (addr, gateway) = start_gateway(1, ListenOptions::default());

    let (status, _, body) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.at("ok").as_bool(), Some(true));
    assert_eq!(j.at("draining").as_bool(), Some(false));

    // No cache was wired into this test gateway.
    let (status, _, body) = http(addr, "GET", "/cache", "");
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.at("enabled").as_bool(), Some(false));

    let (status, _, body) = http(addr, "GET", "/stats", "");
    assert_eq!(status, 200);
    let j = Json::parse(&body).unwrap();
    assert!(j.at("jobs").get("accepted").is_some());

    let (status, _, body) = http(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    assert!(Json::parse(&body).unwrap().get("error").is_some());

    let (status, _, _) = http(addr, "GET", "/jobs", "");
    assert_eq!(status, 405, "wrong method on a known path");

    // Bad job lines inside a stream are per-line rejects, not HTTP
    // errors.
    let body = format!("not json\n{}", request_line(3));
    let (status, _, text) = http(addr, "POST", "/jobs", &body);
    assert_eq!(status, 200);
    let (acks, results) = split_stream(&text);
    assert_eq!((acks.len(), results.len()), (1, 1));
    assert!(text.lines().any(|l| l.contains("\"error\"")));

    let (status, _, _) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.rejected, 1);
    assert_eq!(stats.jobs.done, 1);
    assert_eq!(stats.refused, 0);
}

/// Satellite (PR 5): `Transfer-Encoding: chunked` request bodies on
/// `POST /jobs` — a submitter can stream a session without knowing its
/// total size, the connection stays framed for keep-alive reuse, and
/// non-session endpoints reject chunked bodies with a 400 shape.
#[test]
fn chunked_request_bodies_stream_jobs_sessions() {
    use omgd::jobs::net::{ChunkedReader, ChunkedWriter};

    let lopts = ListenOptions::default();
    let (addr, gateway) = start_gateway(1, lopts);

    let stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut conn = BufReader::new(stream);

    // Stream a 2-job session as one chunk per NDJSON line.
    {
        let mut w = conn.get_ref();
        write!(
            w,
            "POST /jobs HTTP/1.1\r\nHost: omgd-test\r\n\
             Transfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n"
        )
        .unwrap();
        let mut cw = ChunkedWriter::new(&mut w);
        for seed in 0..2u64 {
            cw.write_all(request_line(seed).as_bytes()).unwrap();
            cw.flush().unwrap();
        }
        cw.finish().unwrap();
    }
    let mut status_line = String::new();
    conn.read_line(&mut status_line).unwrap();
    assert!(status_line.starts_with("HTTP/1.1 200"), "{status_line}");
    let mut chunked_resp = false;
    loop {
        let mut h = String::new();
        conn.read_line(&mut h).unwrap();
        let h = h.trim_end().to_ascii_lowercase();
        if h.is_empty() {
            break;
        }
        if h == "transfer-encoding: chunked" {
            chunked_resp = true;
        }
    }
    assert!(chunked_resp);
    let mut session = String::new();
    ChunkedReader::new(&mut conn)
        .read_to_string(&mut session)
        .unwrap();
    let (acks, results) = split_stream(&session);
    assert_eq!((acks.len(), results.len()), (2, 2), "{session}");

    // The socket is still framed: another round works.
    let (status, _, body) =
        keep_alive_round(&mut conn, "GET", "/healthz", &[], "");
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\":true"));

    // A chunked body on a non-session endpoint: 400 error shape, body
    // drained, connection still usable.
    {
        let mut w = conn.get_ref();
        write!(
            w,
            "POST /work/lease HTTP/1.1\r\nHost: omgd-test\r\n\
             Transfer-Encoding: chunked\r\nConnection: keep-alive\r\n\r\n"
        )
        .unwrap();
        let mut cw = ChunkedWriter::new(&mut w);
        cw.write_all(b"{\"worker\":\"x\"}\n").unwrap();
        cw.finish().unwrap();
    }
    let mut status_line = String::new();
    conn.read_line(&mut status_line).unwrap();
    assert!(
        status_line.starts_with("HTTP/1.1 400"),
        "chunked on /work/lease must 400: {status_line}"
    );
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        conn.read_line(&mut h).unwrap();
        let h = h.trim_end().to_ascii_lowercase();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    conn.read_exact(&mut body).unwrap();
    let text = String::from_utf8_lossy(&body);
    assert!(
        text.contains("only supported on POST /jobs"),
        "{text}"
    );
    // …and the connection survives the rejection.
    let (status, _, _) =
        keep_alive_round(&mut conn, "GET", "/healthz", &[], "");
    assert_eq!(status, 200);
    drop(conn);

    let (status, _, _) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 2);
}

/// Tentpole: `GET /metrics` serves well-formed Prometheus text — ≥12
/// families spanning gateway, queue, worker, and training layers,
/// every `# TYPE` paired with a `# HELP`, and cumulative histogram
/// buckets that never decrease and end at `le="+Inf"`.
///
/// The metrics are process-global and this binary's tests run in
/// parallel, so every value assertion is monotonic (`>=`), never `==`.
#[test]
fn metrics_exposition_is_well_formed_prometheus() {
    let (addr, gateway) = start_gateway(1, ListenOptions::default());
    // Run two jobs so the job/queue families are live at scrape time.
    let body: String = (0..2).map(request_line).collect();
    let (status, _, _) = http(addr, "POST", "/jobs", &body);
    assert_eq!(status, 200);

    let (status, headers, text) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert_eq!(
        headers.get("content-type").map(String::as_str),
        Some("text/plain; version=0.0.4; charset=utf-8")
    );

    let mut help = BTreeSet::new();
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# HELP ") {
            help.insert(
                rest.split_whitespace().next().unwrap().to_string(),
            );
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            types.insert(
                it.next().unwrap().to_string(),
                it.next().unwrap().to_string(),
            );
        }
    }
    assert!(
        types.len() >= 12,
        "expected ≥12 metric families, got {}",
        types.len()
    );
    for (name, kind) in &types {
        assert!(name.starts_with("omgd_"), "{name}");
        assert!(help.contains(name), "{name} lacks a # HELP line");
        assert!(
            matches!(kind.as_str(), "counter" | "gauge" | "histogram"),
            "{name}: unknown type {kind}"
        );
    }
    // One family per layer, by exact name (the catalog is an API).
    for name in [
        "omgd_http_requests_total",
        "omgd_jobs_submitted_total",
        "omgd_queue_depth",
        "omgd_queue_wait_seconds",
        "omgd_jobs_completed_total",
        "omgd_leases_granted_total",
        "omgd_job_run_seconds",
        "omgd_cache_hit_seconds",
        "omgd_train_step_seconds",
        "omgd_train_state_bytes",
    ] {
        assert!(types.contains_key(name), "missing family {name}");
    }
    let sample = |name: &str| -> f64 {
        text.lines()
            .find(|l| {
                l.starts_with(name)
                    && l.as_bytes().get(name.len()) == Some(&b' ')
            })
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("no sample line for {name}"))
    };
    assert!(sample("omgd_http_requests_total") >= 2.0);
    assert!(sample("omgd_jobs_submitted_total") >= 2.0);
    assert!(sample("omgd_jobs_completed_total") >= 2.0);
    // Histogram buckets are cumulative: non-decreasing, `+Inf` last.
    for (name, kind) in &types {
        if kind != "histogram" {
            continue;
        }
        let prefix = format!("{name}_bucket{{le=\"");
        let bucket_lines: Vec<&str> =
            text.lines().filter(|l| l.starts_with(&prefix)).collect();
        assert!(!bucket_lines.is_empty(), "{name} has no buckets");
        let counts: Vec<u64> = bucket_lines
            .iter()
            .map(|l| {
                l.split_whitespace().nth(1).unwrap().parse().unwrap()
            })
            .collect();
        assert!(
            counts.windows(2).all(|w| w[0] <= w[1]),
            "{name} buckets must be cumulative: {counts:?}"
        );
        assert!(
            bucket_lines.last().unwrap().contains("le=\"+Inf\""),
            "{name} must close with the +Inf bucket"
        );
    }

    let (status, _, _) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    gateway.join().unwrap();
}

/// Tentpole + satellite: per-phase timings measured by a loopback
/// worker agent come back over the wire — `/stats` phase histograms
/// fill in and the `/events` journal carries lease → report spans
/// with non-zero run durations — and `--metrics off|summary` gate the
/// telemetry endpoints. One test, ordered, because the journal
/// capacity is process-global: the gating gateways disable it, so
/// they must run after the journal assertions.
#[test]
fn distributed_phase_timings_and_metrics_gating() {
    use omgd::jobs::{run_grid_remote, run_worker_with, WorkerOptions};
    use omgd::obs::MetricsLevel;

    let lopts = ListenOptions {
        poll_secs: 2,
        ..ListenOptions::default()
    };
    // Coordinator-only gateway: every job runs on the remote agent.
    let (addr, gateway) = start_gateway(0, lopts);

    // Nonexistent artifacts dir → fingerprint "absent", no sync; the
    // runner sleeps so worker-measured run_secs is provably non-zero.
    let mut specs = Vec::new();
    for seed in 0..3u64 {
        let mut cfg = omgd::config::RunConfig::default();
        cfg.seed = seed;
        cfg.artifacts_dir = "/nonexistent/omgd-net-obs-test".into();
        specs.push(JobSpec {
            kind: omgd::jobs::ExperimentKind::Finetune {
                task: "CoLA".into(),
                epochs: 1,
            },
            cfg,
        });
    }
    let tmp = std::env::temp_dir()
        .join(format!("omgd-net-obs-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let wopts = WorkerOptions {
        connect: addr.to_string(),
        workers: 1,
        worker_id: "w-obs".into(),
        cache_dir: Some(
            tmp.join("cache").to_string_lossy().into_owned(),
        ),
        store_dir: Some(
            tmp.join("store").to_string_lossy().into_owned(),
        ),
        max_failures: 50,
        ..WorkerOptions::default()
    };
    let report = std::thread::scope(|scope| {
        let agent = scope.spawn(|| {
            run_worker_with(&wopts, |_wid| {
                |sp: &JobSpec| {
                    std::thread::sleep(Duration::from_millis(5));
                    Ok(stub_outcome(sp))
                }
            })
            .unwrap()
        });
        let report =
            run_grid_remote(&addr.to_string(), specs, None).unwrap();

        // Phase histograms: ≥3 queue-waits and runs observed, with
        // the 5 ms runs pushing the mean above zero (globals again:
        // monotonic assertions only).
        let (status, _, body) = http(addr, "GET", "/stats", "");
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        let run = j.at("phases").at("run");
        assert!(run.at("count").as_usize().unwrap() >= 3, "{body}");
        assert!(run.at("mean").as_f64().unwrap() > 0.0, "{body}");
        let qw = j.at("phases").at("queue_wait");
        assert!(qw.at("count").as_usize().unwrap() >= 3, "{body}");

        // The journal carries this worker's lease → report spans;
        // report spans carry the wire-reported run duration.
        let (status, headers, events) =
            http(addr, "GET", "/events?n=512", "");
        assert_eq!(status, 200);
        assert_eq!(
            headers.get("content-type").map(String::as_str),
            Some("application/x-ndjson")
        );
        let mine: Vec<Json> = events
            .lines()
            .map(|l| Json::parse(l).expect("journal line is JSON"))
            .filter(|e| e.at("worker").as_str() == Some("w-obs"))
            .collect();
        let leases = mine
            .iter()
            .filter(|e| e.at("kind").as_str() == Some("lease"))
            .count();
        let reports: Vec<&Json> = mine
            .iter()
            .filter(|e| e.at("kind").as_str() == Some("report"))
            .collect();
        assert!(leases >= 3, "want ≥3 lease spans:\n{events}");
        assert!(reports.len() >= 3, "want ≥3 report spans:\n{events}");
        for r in &reports {
            assert!(
                r.at("run_secs").as_f64().unwrap() > 0.0,
                "report spans carry worker-measured run time: {r:?}"
            );
            assert!(r.at("secs").as_f64().unwrap() > 0.0, "{r:?}");
        }

        let (status, _, _) = http(addr, "POST", "/shutdown", "");
        assert_eq!(status, 200);
        agent.join().unwrap();
        report
    });
    assert_eq!(report.n_jobs(), 3);
    assert_eq!(report.n_failed(), 0);
    gateway.join().unwrap();
    std::fs::remove_dir_all(&tmp).ok();

    // `--metrics off`: both telemetry endpoints 404.
    let (addr, gw) = start_gateway(
        1,
        ListenOptions {
            metrics: MetricsLevel::Off,
            ..ListenOptions::default()
        },
    );
    let (status, _, body) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 404, "{body}");
    let (status, _, body) = http(addr, "GET", "/events", "");
    assert_eq!(status, 404, "{body}");
    http(addr, "POST", "/shutdown", "");
    gw.join().unwrap();

    // `--metrics summary`: scrape lives on, the journal does not.
    let (addr, gw) = start_gateway(
        1,
        ListenOptions {
            metrics: MetricsLevel::Summary,
            ..ListenOptions::default()
        },
    );
    let (status, _, _) = http(addr, "GET", "/metrics", "");
    assert_eq!(status, 200);
    let (status, _, body) = http(addr, "GET", "/events", "");
    assert_eq!(status, 404);
    assert!(body.contains("--metrics full"), "{body}");
    http(addr, "POST", "/shutdown", "");
    gw.join().unwrap();
    // Those gateways disabled the process-global journal ring;
    // restore it for anything that scrapes later in this binary.
    omgd::obs::journal().set_capacity(omgd::obs::DEFAULT_JOURNAL_CAP);
}
