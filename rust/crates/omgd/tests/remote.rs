//! Integration tests for distributed execution (`jobs::remote` +
//! `jobs::sync` over the `jobs::net` gateway) on loopback sockets with
//! stub runners — no artifacts, no PJRT.
//!
//! Under test: the PR's acceptance criteria — a grid submitted via
//! `grid --remote` to a gateway with ≥2 worker agents produces
//! byte-identical CSV aggregates to the same grid on a local pool;
//! a worker killed mid-lease has its job re-dispatched (and its late
//! result rejected); a worker starting with an empty artifact store
//! syncs the fingerprinted artifact set before running.

use omgd::jobs::{
    journal, run_gateway, run_grid_remote, run_pool, run_worker_with,
    ArtifactStore, ExperimentKind, GatewayStats, GridReport, JobJournal,
    JobOutcome, JobQueue, JobResult, JobSpec, JobStatus, ListenOptions,
    Record, ResultCache, WorkerOptions,
};
use omgd::config::RunConfig;
use omgd::train::Checkpoint;
use omgd::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("omgd-remote-test-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A finetune cell whose artifacts dir is deliberately nonexistent, so
/// the gateway's fingerprint is deterministically `"absent"` and no
/// sync happens (the stub runners never touch artifacts anyway).
fn spec(seed: u64) -> JobSpec {
    let mut cfg = RunConfig::default();
    cfg.seed = seed;
    cfg.artifacts_dir = "/nonexistent/omgd-remote-test".into();
    JobSpec {
        kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 1 },
        cfg,
    }
}

/// Deterministic stub outcome, a pure function of the spec.
fn stub_outcome(spec: &JobSpec) -> JobOutcome {
    JobOutcome {
        final_metric: spec.cfg.seed as f64 + 0.5,
        tail_loss: 0.25,
        steps: 2,
        train_secs: 0.0,
        loss_series: vec![(0, 1.0)],
        eval_series: vec![],
    }
}

/// The same grid on a local pool — the byte-identical baseline.
fn local_report(specs: Vec<JobSpec>, workers: usize) -> GridReport {
    let queue = JobQueue::bounded(specs.len().max(1));
    for s in specs {
        queue.push(s, 0).unwrap();
    }
    queue.close();
    let results = run_pool(&queue, workers, |_wid| {
        |s: &JobSpec| Ok((stub_outcome(s), false))
    });
    GridReport::new(results)
}

fn csv_bytes(report: &GridReport, tag: &str) -> Vec<u8> {
    let dir = tmp_dir(tag);
    let path = dir.join("grid.csv");
    report.write_csv(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    bytes
}

/// Start a coordinator-only gateway (no local workers, no cache) on a
/// free loopback port.
fn start_gateway(
    lopts: ListenOptions,
) -> (SocketAddr, std::thread::JoinHandle<GatewayStats>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        run_gateway(listener, 0, &lopts, None, |_wid| {
            |_s: &JobSpec| -> anyhow::Result<(JobOutcome, bool)> {
                unreachable!("coordinator-only gateway has no local pool")
            }
        })
        .unwrap()
    });
    (addr, handle)
}

fn worker_opts(addr: SocketAddr, id: &str, tag: &str) -> WorkerOptions {
    WorkerOptions {
        connect: addr.to_string(),
        workers: 2,
        worker_id: id.to_string(),
        cache_dir: Some(
            tmp_dir(&format!("{tag}-cache-{id}"))
                .to_string_lossy()
                .into_owned(),
        ),
        store_dir: Some(
            tmp_dir(&format!("{tag}-store-{id}"))
                .to_string_lossy()
                .into_owned(),
        ),
        force: false,
        max_failures: 50,
        ..WorkerOptions::default()
    }
}

/// One raw HTTP/1.1 round trip (the manual-protocol side of the tests).
fn http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: omgd-test\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut r = BufReader::new(stream);
    let mut status_line = String::new();
    r.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .unwrap();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
    }
    let mut body = String::new();
    r.read_to_string(&mut body).unwrap();
    (status, body)
}

fn shutdown(addr: SocketAddr) {
    let (status, body) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    assert!(body.contains("draining"));
}

#[test]
fn remote_grid_on_two_workers_matches_local_pool_byte_for_byte() {
    let lopts = ListenOptions {
        poll_secs: 2,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    let specs: Vec<JobSpec> = (0..6).map(spec).collect();
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "base-a");

    let (report, wa, wb) = std::thread::scope(|s| {
        let a = s.spawn(|| {
            run_worker_with(&worker_opts(addr, "w-a", "two"), |_wid| {
                |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                    Ok(stub_outcome(s))
                }
            })
            .unwrap()
        });
        let b = s.spawn(|| {
            run_worker_with(&worker_opts(addr, "w-b", "two"), |_wid| {
                |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                    Ok(stub_outcome(s))
                }
            })
            .unwrap()
        });
        let report =
            run_grid_remote(&addr.to_string(), specs, None).unwrap();
        // Grid done: drain the gateway so both agents exit.
        shutdown(addr);
        (report, a.join().unwrap(), b.join().unwrap())
    });

    assert_eq!(report.n_jobs(), 6);
    assert_eq!(report.n_failed(), 0);
    let remote_csv = csv_bytes(&report, "remote-a");
    assert_eq!(
        remote_csv, baseline,
        "remote aggregate must be byte-identical to the local pool's"
    );
    // Both ends agree on the accounting: every job ran exactly once,
    // somewhere.
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 6);
    assert_eq!(stats.jobs.failed, 0);
    assert_eq!(stats.remote.leased, 6);
    assert_eq!(stats.remote.conflicts, 0);
    assert_eq!(wa.done + wb.done, 6);
    assert_eq!(wa.failed + wb.failed, 0);
}

#[test]
fn killed_worker_mid_lease_is_requeued_and_its_late_result_rejected() {
    let lopts = ListenOptions {
        poll_secs: 2,
        lease_secs: 1, // expire fast: the zombie never renews
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    let specs: Vec<JobSpec> = (10..13).map(spec).collect();
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "base-b");

    let (report, zombie_seq, stolen) = std::thread::scope(|s| {
        let grid = s.spawn({
            let specs = specs.clone();
            move || {
                run_grid_remote(&addr.to_string(), specs, None).unwrap()
            }
        });
        // Wait until the session has queued work.
        let mut queued = false;
        for _ in 0..400 {
            let (status, body) = http(addr, "GET", "/healthz", "");
            assert_eq!(status, 200);
            let j = Json::parse(&body).unwrap();
            if j.at("queue_len").as_usize().unwrap_or(0) >= 1 {
                queued = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(queued, "grid session never queued work");
        // A "worker" leases one job and dies (never renews, never
        // reports) — simulated by simply holding the lease reply.
        let (status, body) = http(
            addr,
            "POST",
            "/work/lease",
            "{\"worker\":\"zombie\",\"artifacts\":[]}",
        );
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        let lease = j.get("lease").expect("zombie got a lease");
        let zombie_seq = lease.at("seq").as_usize().unwrap();
        let stolen_seed = lease
            .at("spec")
            .at("seed")
            .as_usize()
            .expect("leases carry the full wire spec");
        // Now a healthy agent joins; after ~1s the zombie's lease
        // expires and its job is re-dispatched to this agent.
        let healthy = s.spawn(|| {
            run_worker_with(&worker_opts(addr, "w-ok", "kill"), |_wid| {
                |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                    Ok(stub_outcome(s))
                }
            })
            .unwrap()
        });
        let report = grid.join().unwrap();
        // The zombie reports its result *after* re-dispatch completed:
        // the gateway must reject it as a conflict, not double-deliver.
        let late = format!(
            "{{\"worker\":\"zombie\",\"status\":\"done\",\
             \"cached\":false,\"secs\":9.9,\"outcome\":\
             {{\"final_metric\":999.0,\"tail_loss\":9.0,\"steps\":9,\
             \"train_secs\":9.0,\"loss_series\":[],\
             \"eval_series\":[]}}}}"
        );
        let (status, body) = http(
            addr,
            "POST",
            &format!("/work/{zombie_seq}/result"),
            &late,
        );
        assert_eq!(status, 409, "late result must conflict: {body}");
        shutdown(addr);
        let _ = healthy.join().unwrap();
        (report, zombie_seq, stolen_seed)
    });

    assert_eq!(report.n_jobs(), 3);
    assert_eq!(
        report.n_failed(),
        0,
        "the re-dispatched job completed despite the dead worker"
    );
    // The lease the zombie held really was one of this grid's cells.
    assert!(zombie_seq < 3, "seq {zombie_seq} out of range");
    assert!((10..13).contains(&stolen), "leased seed {stolen}");
    // And the aggregate is still byte-identical — 999.0 never leaked.
    let remote_csv = csv_bytes(&report, "remote-b");
    assert_eq!(remote_csv, baseline);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 3);
    assert!(stats.remote.requeued >= 1, "expiry re-dispatched the job");
    assert!(stats.remote.conflicts >= 1, "late result was rejected");
}

#[test]
fn empty_store_worker_syncs_artifacts_by_fingerprint_before_running() {
    let lopts = ListenOptions {
        poll_secs: 2,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    // A fake-but-real artifact set on the "gateway" machine.
    let art_dir = tmp_dir("sync-artifacts");
    std::fs::write(art_dir.join("fakemod.json"), b"{\"manifest\":1}")
        .unwrap();
    std::fs::write(
        art_dir.join("fakemod.train.hlo.txt"),
        b"HloModule train\n",
    )
    .unwrap();
    std::fs::write(
        art_dir.join("fakemod.init.bin"),
        [0u8, 1, 2, 253, 254, 255, 10, 13],
    )
    .unwrap();
    std::fs::write(art_dir.join("unrelated.json"), b"{}").unwrap();

    let mk = |seed: u64| {
        let mut s = spec(seed);
        s.cfg.model = "fakemod".into();
        s.cfg.artifacts_dir = art_dir.to_string_lossy().into_owned();
        s
    };
    let specs = vec![mk(0), mk(1)];
    let expect_fp = omgd::jobs::artifact_fingerprint(&specs[0].cfg);
    assert_ne!(expect_fp, "absent", "fixture artifacts must fingerprint");

    // The stub runner records the artifacts dir each job actually saw
    // and verifies the synced bytes match the originals.
    let seen: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let art_src = art_dir.clone();
    let (report, wstats) = std::thread::scope(|s| {
        let worker = s.spawn(|| {
            let mut opts = worker_opts(addr, "w-sync", "sync");
            opts.workers = 1; // serialize: exactly one sync expected
            run_worker_with(&opts, |_wid| {
                |js: &JobSpec| -> anyhow::Result<JobOutcome> {
                    let dir = PathBuf::from(&js.cfg.artifacts_dir);
                    assert_ne!(
                        dir, art_src,
                        "worker must run against its own synced copy"
                    );
                    for name in
                        ["fakemod.json", "fakemod.train.hlo.txt",
                         "fakemod.init.bin"]
                    {
                        let synced = std::fs::read(dir.join(name))
                            .expect("synced file exists");
                        let orig =
                            std::fs::read(art_src.join(name)).unwrap();
                        assert_eq!(synced, orig, "{name} byte-identical");
                    }
                    assert!(
                        !dir.join("unrelated.json").exists(),
                        "foreign files are not synced"
                    );
                    seen.lock()
                        .unwrap()
                        .push(js.cfg.artifacts_dir.clone());
                    Ok(stub_outcome(js))
                }
            })
            .unwrap()
        });
        let report =
            run_grid_remote(&addr.to_string(), specs.clone(), None)
                .unwrap();
        shutdown(addr);
        (report, worker.join().unwrap())
    });

    assert_eq!(report.n_jobs(), 2);
    assert_eq!(report.n_failed(), 0, "both synced cells ran");
    assert_eq!(wstats.synced, 1, "one artifact set, fetched once");
    let seen = seen.into_inner().unwrap();
    assert_eq!(seen.len(), 2);
    assert_eq!(seen[0], seen[1], "both jobs share the synced copy");
    assert!(
        seen[0].contains(&expect_fp),
        "store keys by the gateway fingerprint: {} vs {expect_fp}",
        seen[0]
    );
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 2);
    std::fs::remove_dir_all(&art_dir).ok();
}

/// `GET /artifacts/<fp>` error shapes: unknown fingerprints 404; a
/// fingerprint whose files changed since the lease 409s ("stale").
#[test]
fn artifact_endpoint_rejects_unknown_and_stale_fingerprints() {
    let lopts = ListenOptions {
        poll_secs: 1,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    let (status, body) =
        http(addr, "GET", "/artifacts/0123456789abcdef", "");
    assert_eq!(status, 404, "unknown fingerprint: {body}");

    let art_dir = tmp_dir("stale-artifacts");
    std::fs::write(art_dir.join("m.json"), b"v1").unwrap();
    let mut s = spec(0);
    s.cfg.model = "m".into();
    s.cfg.artifacts_dir = art_dir.to_string_lossy().into_owned();
    let fp = omgd::jobs::artifact_fingerprint(&s.cfg);

    // Submit + manually lease so the gateway registers the fingerprint.
    let grid = {
        let specs = vec![s];
        std::thread::spawn(move || {
            // The job will be completed manually below.
            run_grid_remote(&addr.to_string(), specs, None)
        })
    };
    let mut lease = None;
    for _ in 0..50 {
        let (status, body) = http(
            addr,
            "POST",
            "/work/lease",
            "{\"worker\":\"manual\",\"artifacts\":[]}",
        );
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        if j.get("lease").is_some() {
            lease = Some(j);
            break;
        }
    }
    let lease = lease.expect("grid job never became leasable");
    let leased = lease.get("lease").unwrap();
    assert_eq!(leased.at("afp").as_str(), Some(fp.as_str()));

    // Regenerate the artifact after the lease: same name, new content.
    std::thread::sleep(Duration::from_millis(20));
    std::fs::write(art_dir.join("m.json"), b"v2-regenerated").unwrap();
    let (status, body) = http(addr, "GET", &format!("/artifacts/{fp}"), "");
    assert_eq!(status, 409, "stale fingerprint must 409: {body}");
    assert!(body.contains("stale"));

    // Finish the leased job so the grid session drains.
    let seq = leased.at("seq").as_usize().unwrap();
    let done = "{\"worker\":\"manual\",\"status\":\"failed\",\
                \"secs\":0.1,\"error\":\"fixture\"}";
    let (status, _) =
        http(addr, "POST", &format!("/work/{seq}/result"), done);
    assert_eq!(status, 200);
    let report = grid.join().unwrap().unwrap();
    assert_eq!(report.n_failed(), 1);
    shutdown(addr);
    gateway.join().unwrap();
    std::fs::remove_dir_all(&art_dir).ok();
}

/// Tentpole acceptance: with two workers whose artifact stores cover
/// disjoint halves of a mixed grid, affinity leasing routes every cell
/// to the worker that already holds its artifact set — zero redundant
/// syncs, `remote.affinity` visible in `/stats`, and the aggregate
/// still byte-identical to a local pool. Placement is deterministic:
/// the whole grid is queued before either worker polls, and each
/// worker's `--max-jobs` budget equals exactly its half (which also
/// exercises the lifecycle knob end to end).
#[test]
fn affinity_routes_cells_to_artifact_holders_with_zero_resync() {
    let lopts = ListenOptions {
        poll_secs: 2,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    // Two disjoint artifact sets ("models") on the gateway host.
    let art = tmp_dir("aff-artifacts");
    std::fs::write(art.join("ma.json"), b"{\"m\":\"a\"}").unwrap();
    std::fs::write(art.join("mb.json"), b"{\"m\":\"b\"}").unwrap();
    let mk = |model: &str, seed: u64| {
        let mut s = spec(seed);
        s.cfg.model = model.to_string();
        s.cfg.artifacts_dir = art.to_string_lossy().into_owned();
        s
    };
    // ma cells lead the queue: a cache-blind scheduler's oldest-first
    // pop would hand worker B an ma cell (and force a sync).
    let specs =
        vec![mk("ma", 0), mk("ma", 1), mk("mb", 2), mk("mb", 3)];
    let fp_a = omgd::jobs::artifact_fingerprint(&specs[0].cfg);
    let fp_b = omgd::jobs::artifact_fingerprint(&specs[2].cfg);
    assert_ne!(fp_a, "absent");
    assert_ne!(fp_a, fp_b);
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "aff-base");

    // Pre-seed each worker's store with ITS half, as if synced on an
    // earlier grid; each agent runs one thread with a 2-job budget.
    let mut opts_a = worker_opts(addr, "w-aff-a", "aff");
    opts_a.workers = 1;
    opts_a.max_jobs = 2;
    let mut opts_b = worker_opts(addr, "w-aff-b", "aff");
    opts_b.workers = 1;
    opts_b.max_jobs = 2;
    let store_a = ArtifactStore::open(opts_a.store_dir.as_deref()).unwrap();
    store_a
        .ensure(&fp_a, || omgd::jobs::sync::pack(&art, "ma"))
        .unwrap();
    let store_b = ArtifactStore::open(opts_b.store_dir.as_deref()).unwrap();
    store_b
        .ensure(&fp_b, || omgd::jobs::sync::pack(&art, "mb"))
        .unwrap();

    let (report, wa, wb, affinity) = std::thread::scope(|s| {
        let grid = s.spawn({
            let specs = specs.clone();
            move || {
                run_grid_remote(&addr.to_string(), specs, None).unwrap()
            }
        });
        // Every cell queued before the first poll → every scan sees
        // the full grid.
        let mut queued = false;
        for _ in 0..400 {
            let (status, body) = http(addr, "GET", "/healthz", "");
            assert_eq!(status, 200);
            let j = Json::parse(&body).unwrap();
            if j.at("queue_len").as_usize().unwrap_or(0) == 4 {
                queued = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(queued, "grid session never queued all 4 cells");
        let run = |opts: WorkerOptions| {
            move || {
                run_worker_with(&opts, |_wid| {
                    |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                        Ok(stub_outcome(s))
                    }
                })
                .unwrap()
            }
        };
        let a = s.spawn(run(opts_a));
        let b = s.spawn(run(opts_b));
        let report = grid.join().unwrap();
        // Snapshot /stats before shutdown resets nothing — affinity
        // is hub-lifetime, but the gateway exits after drain.
        let (status, body) = http(addr, "GET", "/stats", "");
        assert_eq!(status, 200);
        let affinity = Json::parse(&body)
            .unwrap()
            .at("remote")
            .at("affinity")
            .as_usize();
        shutdown(addr);
        (report, a.join().unwrap(), b.join().unwrap(), affinity)
    });

    assert_eq!(report.n_jobs(), 4);
    assert_eq!(report.n_failed(), 0);
    assert_eq!(
        (wa.leased, wb.leased),
        (2, 2),
        "the --max-jobs budget split the grid evenly"
    );
    assert_eq!(
        (wa.synced, wb.synced),
        (0, 0),
        "affinity placement makes every sync redundant"
    );
    assert_eq!(affinity, Some(4), "every lease was an affinity match");
    let remote_csv = csv_bytes(&report, "aff-remote");
    assert_eq!(remote_csv, baseline);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 4);
    assert_eq!(stats.remote.leased, 4);
    assert_eq!(stats.remote.affinity, 4);
    std::fs::remove_dir_all(&art).ok();
}

/// Lifecycle: an agent pointed at an idle gateway exits on its own via
/// `--idle-exit`, without waiting for a drain signal.
#[test]
fn idle_worker_exits_via_idle_exit_without_drain() {
    let lopts = ListenOptions {
        poll_secs: 1,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);
    let mut opts = worker_opts(addr, "w-idle", "idle");
    opts.workers = 1;
    opts.idle_exit_secs = 1;
    let t0 = Instant::now();
    let stats = run_worker_with(&opts, |_wid| {
        |_s: &JobSpec| -> anyhow::Result<JobOutcome> {
            unreachable!("no jobs were ever submitted")
        }
    })
    .unwrap();
    assert_eq!(stats.leased, 0);
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "idle-exit must beat the drain-or-die default"
    );
    shutdown(addr);
    gateway.join().unwrap();
}

/// Parse one HTTP request on a fake-gateway socket: returns
/// `"METHOD /path"` and the number of NDJSON body lines (chunked
/// bodies are de-framed, Content-Length bodies read whole).
fn read_request(c: &mut TcpStream) -> (String, usize) {
    let mut r = BufReader::new(c.try_clone().unwrap());
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let head = line
        .split_whitespace()
        .take(2)
        .collect::<Vec<_>>()
        .join(" ");
    let mut chunked = false;
    let mut clen = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let t = h.trim_end().to_ascii_lowercase();
        if t.is_empty() {
            break;
        }
        if t.starts_with("transfer-encoding:") && t.contains("chunked") {
            chunked = true;
        }
        if let Some(v) = t.strip_prefix("content-length:") {
            clen = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = Vec::new();
    if chunked {
        loop {
            let mut sz = String::new();
            r.read_line(&mut sz).unwrap();
            let n = usize::from_str_radix(sz.trim(), 16).unwrap();
            let mut buf = vec![0u8; n + 2]; // chunk + CRLF
            r.read_exact(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            body.extend_from_slice(&buf[..n]);
        }
    } else if clen > 0 {
        body = vec![0u8; clen];
        r.read_exact(&mut body).unwrap();
    }
    let lines = body
        .split(|&b| b == b'\n')
        .filter(|l| !l.is_empty())
        .count();
    (head, lines)
}

/// Write one `Content-Length`-framed, `Connection: close` response on
/// a fake-gateway socket (the shape `GatewayConn` re-polls expect).
fn respond(c: &mut TcpStream, status: u16, reason: &str, body: &str) {
    write!(
        c,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\
         \r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    c.flush().unwrap();
}

/// A result line / `GET /jobs/<seq>/result` body for `spec`, carrying
/// the deterministic stub outcome.
fn result_json(seq: u64, s: &JobSpec) -> String {
    let o = stub_outcome(s);
    format!(
        "{{\"seq\":{seq},\"label\":\"{}\",\"hash\":\"{}\",\
         \"status\":\"done\",\"cached\":false,\"final_metric\":{},\
         \"tail_loss\":{},\"steps\":{},\"secs\":0.0}}",
        s.label(),
        s.hash_hex(),
        o.final_metric,
        o.tail_loss,
        o.steps,
    )
}

/// Durability satellite, gateway side: the coordinator "crashes"
/// leaving a dirty journal — one job finished, one leased to a worker
/// that died with it, one still queued, and a torn half-record from
/// the fatal write. A restart on the same cache dir must replay it:
/// the finished result answers `GET /jobs/<seq>/result` immediately,
/// the unfinished jobs are re-dispatched to a fresh agent, and the
/// aggregate a reconnecting client assembles by re-polling its seqs is
/// byte-identical to an uninterrupted local pool. Clean shutdown then
/// compacts the journal to exactly the live state.
#[test]
fn coordinator_restart_replays_dirty_journal_and_serves_repolls() {
    let dir = tmp_dir("journal-restart");
    let specs: Vec<JobSpec> = (30..33).map(spec).collect();
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "base-j");

    // The pre-crash history, exactly as the dying gateway fsynced it.
    {
        let j = JobJournal::open(&dir).unwrap();
        for (i, s) in specs.iter().enumerate() {
            j.append(&Record::Admit {
                seq: i as u64,
                priority: 0,
                client: None,
                spec: s.clone(),
            })
            .unwrap();
        }
        j.append(&Record::Done {
            seq: 0,
            status: JobStatus::Done(stub_outcome(&specs[0])),
            from_cache: false,
            secs: 0.0,
            spec: specs[0].clone(),
        })
        .unwrap();
        j.append(&Record::Lease { seq: 1, worker: "w-dead".into() })
            .unwrap();
    }
    // The crash tore the final record mid-write.
    {
        let mut f = std::fs::OpenOptions::new()
            .append(true)
            .open(JobJournal::path_in(&dir))
            .unwrap();
        f.write_all(b"00deadbeef00cafe {\"rec\":\"don").unwrap();
    }

    // "Restart" on the same cache dir.
    let lopts = ListenOptions {
        poll_secs: 2,
        lease_secs: 1, // the dead worker's lease expires fast
        journal_dir: Some(dir.clone()),
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    // Replayed result → immediately re-pollable; replayed-but-
    // unfinished → pending; unknown seq → 404 (resubmit); junk → 400.
    let (status, body) = http(addr, "GET", "/jobs/0/result", "");
    assert_eq!(status, 200, "{body}");
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.at("status").as_str(), Some("done"));
    assert_eq!(j.at("final_metric").as_f64(), Some(30.5));
    let (status, body) = http(addr, "GET", "/jobs/1/result", "");
    assert_eq!(status, 202, "{body}");
    assert!(body.contains("\"pending\":true"), "{body}");
    let (status, _) = http(addr, "GET", "/jobs/2/result", "");
    assert_eq!(status, 202);
    let (status, body) = http(addr, "GET", "/jobs/999/result", "");
    assert_eq!(status, 404, "{body}");
    assert!(body.contains("resubmit"), "{body}");
    let (status, _) = http(addr, "GET", "/jobs/abc/result", "");
    assert_eq!(status, 400);

    let wstats = std::thread::scope(|s| {
        // A fresh agent drains the two replayed jobs (seq 1's dead
        // lease expires first, then it re-dispatches).
        let w = s.spawn(|| {
            run_worker_with(&worker_opts(addr, "w-r", "jrnl"), |_wid| {
                |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                    Ok(stub_outcome(s))
                }
            })
            .unwrap()
        });
        // The reconnecting client: re-poll every seq it was acked
        // before the crash, exactly as `grid --remote` does.
        let mut results = Vec::new();
        for (i, sp) in specs.iter().enumerate() {
            let mut got = None;
            for _ in 0..600 {
                let (status, body) =
                    http(addr, "GET", &format!("/jobs/{i}/result"), "");
                match status {
                    200 => {
                        got = Some(Json::parse(&body).unwrap());
                        break;
                    }
                    202 => {
                        std::thread::sleep(Duration::from_millis(50))
                    }
                    other => panic!("unexpected HTTP {other}: {body}"),
                }
            }
            let j =
                got.unwrap_or_else(|| panic!("seq {i} never finished"));
            assert_eq!(
                j.at("hash").as_str(),
                Some(sp.hash_hex().as_str()),
                "journal preserved the spec identity across the crash"
            );
            let f = |k: &str| j.at(k).as_f64().unwrap();
            results.push(JobResult {
                seq: i as u64,
                spec: sp.clone(),
                status: JobStatus::Done(JobOutcome {
                    final_metric: f("final_metric"),
                    tail_loss: f("tail_loss"),
                    steps: j.at("steps").as_usize().unwrap(),
                    train_secs: 0.0,
                    loss_series: Vec::new(),
                    eval_series: Vec::new(),
                }),
                from_cache: false,
                secs: 0.0,
            });
        }
        let report = GridReport::new(results);
        assert_eq!(
            csv_bytes(&report, "jrnl-remote"),
            baseline,
            "re-polled aggregate byte-identical to the local pool's"
        );
        shutdown(addr);
        w.join().unwrap()
    });
    assert_eq!(wstats.done, 2, "both unfinished jobs were re-run");
    let stats = gateway.join().unwrap();
    // 1 replayed completion + 2 fresh ones.
    assert_eq!(stats.jobs.done, 3);

    // Clean shutdown compacted: the journal now replays to exactly
    // the live state — no pending work, all three results retained,
    // no torn tail, seq counter preserved.
    let rep = journal::replay(&JobJournal::path_in(&dir)).unwrap();
    assert_eq!(rep.torn, 0);
    assert!(rep.pending.is_empty(), "pending: {:?}", rep.pending.len());
    assert_eq!(rep.completed.len(), 3);
    assert_eq!(rep.next_seq, 3);
    std::fs::remove_dir_all(&dir).ok();
}

/// Durability satellite, client side: a gateway stand-in acks a whole
/// grid, streams one result, then drops the socket (the "crash"); on
/// re-poll it serves one result late (202 → 200) and disowns the last
/// seq (404), forcing a clean resubmission of just that spec.
/// `run_grid_remote` must absorb all of it — no failed cells, and the
/// aggregate byte-identical to the local pool.
#[test]
fn grid_client_reconnects_and_repolls_after_stream_loss() {
    let specs: Vec<JobSpec> = (40..43).map(spec).collect();
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "base-rp");

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let fake = std::thread::spawn({
        let specs = specs.clone();
        move || {
            // Conn 1: ack all three cells, stream ONE result, then cut
            // the connection mid-stream.
            let (mut c, _) = listener.accept().unwrap();
            let (head, n) = read_request(&mut c);
            assert_eq!(head, "POST /jobs");
            assert_eq!(n, 3, "three specs submitted");
            let mut resp = String::from(
                "HTTP/1.1 200 OK\r\nContent-Type: \
                 application/x-ndjson\r\nConnection: close\r\n\r\n",
            );
            for (i, s) in specs.iter().enumerate() {
                resp.push_str(&format!(
                    "{{\"accepted\":{},\"hash\":\"{}\"}}\n",
                    100 + i,
                    s.hash_hex()
                ));
            }
            resp.push_str(&result_json(100, &specs[0]));
            resp.push('\n');
            c.write_all(resp.as_bytes()).unwrap();
            c.flush().unwrap();
            drop(c); // two cells acked but unresolved

            // The client re-polls seq 101: still running, then done.
            let (mut c, _) = listener.accept().unwrap();
            let (head, _) = read_request(&mut c);
            assert_eq!(head, "GET /jobs/101/result");
            respond(
                &mut c,
                202,
                "Accepted",
                "{\"pending\":true,\"seq\":101}",
            );
            let (mut c, _) = listener.accept().unwrap();
            let (head, _) = read_request(&mut c);
            assert_eq!(head, "GET /jobs/101/result");
            let body = result_json(101, &specs[1]);
            respond(&mut c, 200, "OK", &body);

            // Seq 102 is disowned: the client must resubmit the spec.
            let (mut c, _) = listener.accept().unwrap();
            let (head, _) = read_request(&mut c);
            assert_eq!(head, "GET /jobs/102/result");
            respond(
                &mut c,
                404,
                "Not Found",
                "{\"error\":\"no journaled job with seq 102 \
                 (resubmit the spec)\"}",
            );

            // Conn 5: exactly the one disowned spec comes back; serve
            // it to completion and close cleanly.
            let (mut c, _) = listener.accept().unwrap();
            let (head, n) = read_request(&mut c);
            assert_eq!(head, "POST /jobs");
            assert_eq!(n, 1, "only the disowned cell is resubmitted");
            let resp = format!(
                "HTTP/1.1 200 OK\r\nConnection: close\r\n\r\n\
                 {{\"accepted\":103,\"hash\":\"{}\"}}\n{}\n",
                specs[2].hash_hex(),
                result_json(103, &specs[2]),
            );
            c.write_all(resp.as_bytes()).unwrap();
            c.flush().unwrap();
        }
    });

    let report =
        run_grid_remote(&addr.to_string(), specs.clone(), None).unwrap();
    fake.join().unwrap();

    assert_eq!(report.n_jobs(), 3);
    assert_eq!(
        report.n_failed(),
        0,
        "stream loss, late result, and disowned seq all recovered"
    );
    let remote_csv = csv_bytes(&report, "rp-remote");
    assert_eq!(remote_csv, baseline);
}

/// Sanity net for the aggregation math used above: metrics grouped per
/// method over a mixed local report (keeps `mean_metric_by` honest for
/// remote-built reports too).
#[test]
fn remote_reports_aggregate_like_local_ones() {
    let specs: Vec<JobSpec> = (0..4).map(spec).collect();
    let rep = local_report(specs, 2);
    let by: BTreeMap<String, f64> =
        rep.mean_metric_by(|r| r.spec.cfg.method.name().to_string());
    assert_eq!(by.len(), 1);
    // seeds 0..4 → metrics 0.5,1.5,2.5,3.5 → mean 2.0
    assert!((by.iter().next().unwrap().1 - 2.0).abs() < 1e-12);
}

/// A tiny controllable TCP relay between a worker and the gateway.
/// [`FlakyProxy::partition`] severs every live connection and refuses
/// new ones — the in-process stand-in for a worker host dying
/// mid-lease; [`FlakyProxy::restore`] lets traffic flow again.
#[derive(Clone)]
struct FlakyProxy {
    addr: SocketAddr,
    black: Arc<AtomicBool>,
    live: Arc<Mutex<Vec<TcpStream>>>,
}

fn start_proxy(upstream: SocketAddr) -> FlakyProxy {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let proxy = FlakyProxy {
        addr: listener.local_addr().unwrap(),
        black: Arc::new(AtomicBool::new(false)),
        live: Arc::new(Mutex::new(Vec::new())),
    };
    let p = proxy.clone();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(client) = conn else { break };
            if p.black.load(Ordering::SeqCst) {
                continue; // refuse (drop) while partitioned
            }
            let Ok(server) = TcpStream::connect(upstream) else {
                continue;
            };
            {
                let mut l = p.live.lock().unwrap();
                l.push(client.try_clone().unwrap());
                l.push(server.try_clone().unwrap());
            }
            let (mut cr, mut sw) =
                (client.try_clone().unwrap(), server.try_clone().unwrap());
            std::thread::spawn(move || {
                let _ = std::io::copy(&mut cr, &mut sw);
                let _ = sw.shutdown(Shutdown::Both);
            });
            let (mut sr, mut cw) = (server, client);
            std::thread::spawn(move || {
                let _ = std::io::copy(&mut sr, &mut cw);
                let _ = cw.shutdown(Shutdown::Both);
            });
        }
    });
    proxy
}

impl FlakyProxy {
    fn partition(&self) {
        self.black.store(true, Ordering::SeqCst);
        for c in self.live.lock().unwrap().drain(..) {
            let _ = c.shutdown(Shutdown::Both);
        }
    }
    fn restore(&self) {
        self.black.store(false, Ordering::SeqCst);
    }
}

/// Durability satellite, worker side: a worker killed between its
/// checkpoint write and the lease report must leave the checkpoint
/// PARKED, and the re-dispatched lease must resume from it.
///
/// In-process stand-in for the kill: the worker's network is severed
/// right after the checkpoint write, so the report is dropped exactly
/// as a dead host's would be (`post_result` → `reported = false`, the
/// `lease.report` faultpoint window), the un-renewed lease expires,
/// the gateway re-dispatches, and the healed worker's second run
/// finds the parked checkpoint, finishes, and retires it.
#[test]
fn dropped_report_parks_checkpoint_for_the_next_lease() {
    let lopts = ListenOptions {
        poll_secs: 1,
        lease_secs: 1,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);
    let proxy = start_proxy(addr);

    let specs = vec![spec(50)];
    let hash = specs[0].hash_hex();
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "base-ck");

    // The worker talks to the gateway only through the proxy; the
    // grid client below connects directly and never flakes.
    let mut opts = worker_opts(proxy.addr, "w-ck", "ckpark");
    opts.workers = 1;
    opts.ckpt_period = 4; // arm the checkpoint lifecycle in run_lease
    let cache_dir = opts.cache_dir.clone().unwrap();

    let runs = AtomicUsize::new(0);
    let (report, wstats) = std::thread::scope(|s| {
        let w = s.spawn(|| {
            run_worker_with(&opts, |_wid| {
                |js: &JobSpec| -> anyhow::Result<JobOutcome> {
                    let cache =
                        ResultCache::open(Some(cache_dir.as_str()))
                            .unwrap();
                    let h = js.hash_hex();
                    if runs.fetch_add(1, Ordering::SeqCst) == 0 {
                        // "ckpt.write" happened: step 4 is durable...
                        cache
                            .put_checkpoint(&h, &Checkpoint::new(4, 7))
                            .unwrap();
                        // ...and the host dies before "lease.report":
                        // sever the network now, heal it once the
                        // lease has expired at the gateway.
                        proxy.partition();
                        let p = proxy.clone();
                        std::thread::spawn(move || {
                            std::thread::sleep(Duration::from_millis(
                                2500,
                            ));
                            p.restore();
                        });
                        anyhow::bail!("host died mid-run (simulated)");
                    }
                    // Re-dispatched lease: the parked checkpoint is
                    // what makes this a resume, not a restart.
                    let ck = cache
                        .latest_checkpoint(&h)
                        .expect("checkpoint parked by dropped report");
                    assert_eq!(ck.step, 4);
                    Ok(stub_outcome(js))
                }
            })
            .unwrap()
        });
        let report =
            run_grid_remote(&addr.to_string(), specs.clone(), None)
                .unwrap();
        shutdown(addr);
        (report, w.join().unwrap())
    });

    assert_eq!(
        runs.load(Ordering::SeqCst),
        2,
        "one dropped run, one resumed re-dispatch"
    );
    assert_eq!(wstats.leased, 2);
    assert_eq!(wstats.done, 1);
    assert_eq!(wstats.failed, 1);
    assert_eq!(
        wstats.conflicts, 1,
        "the severed report must be counted as dropped"
    );
    assert_eq!(
        report.n_failed(),
        0,
        "the client only ever sees the resumed completion"
    );
    assert_eq!(csv_bytes(&report, "ck-remote"), baseline);

    // A successfully reported Done retires the spec's parked file.
    let cache = ResultCache::open(Some(cache_dir.as_str())).unwrap();
    assert!(
        cache.latest_checkpoint(&hash).is_none(),
        "reported Done retires the parked checkpoint"
    );
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 1);
    assert!(
        stats.remote.requeued >= 1,
        "lease expiry re-dispatched the job"
    );
}
