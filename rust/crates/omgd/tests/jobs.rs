//! Integration tests for the `jobs` subsystem — queue, pool, cache,
//! report — using stub runners, so they execute on any machine with no
//! AOT artifacts and no PJRT runtime.
//!
//! The hard requirement under test: grids are *deterministic in the
//! worker count* and *deterministic under cache replay*. A 2-worker run
//! must write byte-identical CSV aggregates to a 1-worker run, and a
//! second invocation must serve from cache without changing the bytes.

use omgd::config::{Method, RunConfig};
use omgd::jobs::{
    run_pool, ExperimentKind, GridReport, JobOutcome, JobQueue, JobSpec,
    JobStatus, ResultCache,
};
use std::path::PathBuf;

fn spec(method: Method, seed: u64) -> JobSpec {
    let mut cfg = RunConfig::default();
    cfg.method = method;
    cfg.seed = seed;
    JobSpec {
        kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 2 },
        cfg,
    }
}

/// Method × 3 seeds — the acceptance-criteria grid shape.
fn method_x_seeds() -> Vec<JobSpec> {
    let mut specs = Vec::new();
    for method in [Method::Full, Method::Lisa, Method::LisaWor] {
        for seed in 0..3u64 {
            specs.push(spec(method, seed));
        }
    }
    specs
}

/// Deterministic pseudo-outcome derived only from the spec hash.
fn stub_outcome(s: &JobSpec) -> JobOutcome {
    let h = s.content_hash();
    JobOutcome {
        final_metric: 50.0 + (h % 500) as f64 / 10.0,
        tail_loss: (h % 97) as f64 / 100.0,
        steps: 8,
        train_secs: 0.0,
        loss_series: (0..8)
            .map(|i| (i, 2.0 / (1.0 + i as f64 + (h % 7) as f64)))
            .collect(),
        eval_series: vec![(7, 1.0, 60.0)],
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("omgd-jobs-it-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn run_stub_grid(specs: Vec<JobSpec>, workers: usize) -> GridReport {
    let queue = JobQueue::bounded(specs.len().max(1));
    for s in specs {
        queue.push(s, 0).unwrap();
    }
    queue.close();
    let results = run_pool(&queue, workers, |_wid| {
        |s: &JobSpec| -> anyhow::Result<(JobOutcome, bool)> {
            Ok((stub_outcome(s), false))
        }
    });
    GridReport::new(results)
}

/// Like the production `cached_runner`, but over the stub executor.
fn run_cached_stub_grid(
    specs: Vec<JobSpec>,
    workers: usize,
    cache: &ResultCache,
    force: bool,
) -> GridReport {
    let queue = JobQueue::bounded(specs.len().max(1));
    for s in specs {
        queue.push(s, 0).unwrap();
    }
    queue.close();
    let results = run_pool(&queue, workers, |_wid| {
        move |s: &JobSpec| -> anyhow::Result<(JobOutcome, bool)> {
            if force {
                cache.invalidate(s);
            } else if let Some(out) = cache.get(s, "stub-afp") {
                return Ok((out, true));
            }
            let out = stub_outcome(s);
            cache.put(s, "stub-afp", &out)?;
            Ok((out, false))
        }
    });
    GridReport::new(results)
}

#[test]
fn queue_orders_fifo_and_by_priority() {
    let q = JobQueue::bounded(8);
    q.push(spec(Method::Full, 0), 0).unwrap();
    q.push(spec(Method::Full, 1), 2).unwrap();
    q.push(spec(Method::Full, 2), 2).unwrap();
    q.push(spec(Method::Full, 3), 1).unwrap();
    q.close();
    let seeds: Vec<u64> =
        std::iter::from_fn(|| q.pop()).map(|j| j.spec.cfg.seed).collect();
    // Priority 2 first (FIFO within), then 1, then 0.
    assert_eq!(seeds, vec![1, 2, 3, 0]);
}

#[test]
fn pool_isolates_panics_and_finishes_the_grid() {
    let specs = method_x_seeds();
    let n = specs.len();
    let queue = JobQueue::bounded(n);
    for s in specs {
        queue.push(s, 0).unwrap();
    }
    queue.close();
    let results = run_pool(&queue, 3, |_wid| {
        |s: &JobSpec| -> anyhow::Result<(JobOutcome, bool)> {
            if s.cfg.method == Method::Lisa && s.cfg.seed == 1 {
                panic!("poisoned cell");
            }
            Ok((stub_outcome(s), false))
        }
    });
    assert_eq!(results.len(), n, "pool must survive the poisoned job");
    let panicked = results
        .iter()
        .filter(|r| matches!(r.status, JobStatus::Panicked(_)))
        .count();
    assert_eq!(panicked, 1);
    assert_eq!(results.iter().filter(|r| r.is_ok()).count(), n - 1);
}

#[test]
fn two_worker_grid_matches_one_worker_byte_for_byte() {
    let dir = tmp_dir("determinism");
    let rep1 = run_stub_grid(method_x_seeds(), 1);
    let rep2 = run_stub_grid(method_x_seeds(), 2);
    let rep4 = run_stub_grid(method_x_seeds(), 4);

    let (p1, p2, p4) =
        (dir.join("w1.csv"), dir.join("w2.csv"), dir.join("w4.csv"));
    rep1.write_csv(&p1).unwrap();
    rep2.write_csv(&p2).unwrap();
    rep4.write_csv(&p4).unwrap();
    let b1 = std::fs::read(&p1).unwrap();
    assert_eq!(b1, std::fs::read(&p2).unwrap(),
               "1-worker vs 2-worker aggregates must be byte-identical");
    assert_eq!(b1, std::fs::read(&p4).unwrap());

    // Curve files too (per-step series, not just finals).
    let (c1, c2) = (dir.join("c1.csv"), dir.join("c2.csv"));
    rep1.write_curves_csv(&c1).unwrap();
    rep2.write_curves_csv(&c2).unwrap();
    assert_eq!(std::fs::read(&c1).unwrap(), std::fs::read(&c2).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn second_invocation_hits_cache_and_replays_identically() {
    let dir = tmp_dir("cache-replay");
    let cache_dir = dir.join("cache");
    let cache =
        ResultCache::open(Some(cache_dir.to_str().unwrap())).unwrap();

    let fresh = run_cached_stub_grid(method_x_seeds(), 2, &cache, false);
    assert_eq!(fresh.n_ok(), 9);
    assert_eq!(fresh.n_cached(), 0);
    assert_eq!(cache.len(), 9);

    // Second invocation: ≥ 90% cache hits (here: all of them), no
    // recomputation, byte-identical aggregate.
    let replay = run_cached_stub_grid(method_x_seeds(), 2, &cache, false);
    assert_eq!(replay.n_cached(), 9);
    assert!(replay.cache_hit_rate() >= 0.9);

    let (p1, p2) = (dir.join("fresh.csv"), dir.join("replay.csv"));
    fresh.write_csv(&p1).unwrap();
    replay.write_csv(&p2).unwrap();
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap(),
               "cache replay must not change the aggregate bytes");

    // --force invalidates every cell and recomputes.
    let forced = run_cached_stub_grid(method_x_seeds(), 2, &cache, true);
    assert_eq!(forced.n_cached(), 0);
    assert_eq!(cache.len(), 9, "forced run repopulates the cache");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mixed_grids_share_overlapping_cells() {
    let dir = tmp_dir("overlap");
    let cache = ResultCache::open(Some(dir.to_str().unwrap())).unwrap();
    run_cached_stub_grid(vec![spec(Method::Full, 0)], 1, &cache, false);
    // A bigger grid containing the same cell: 1 hit, 2 fresh.
    let rep = run_cached_stub_grid(
        vec![spec(Method::Full, 0), spec(Method::Full, 1),
             spec(Method::LisaWor, 0)],
        2,
        &cache,
        false,
    );
    assert_eq!(rep.n_cached(), 1);
    assert_eq!(cache.len(), 3);
    std::fs::remove_dir_all(&dir).ok();
}
