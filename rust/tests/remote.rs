//! Integration tests for distributed execution (`jobs::remote` +
//! `jobs::sync` over the `jobs::net` gateway) on loopback sockets with
//! stub runners — no artifacts, no PJRT.
//!
//! Under test: the PR's acceptance criteria — a grid submitted via
//! `grid --remote` to a gateway with ≥2 worker agents produces
//! byte-identical CSV aggregates to the same grid on a local pool;
//! a worker killed mid-lease has its job re-dispatched (and its late
//! result rejected); a worker starting with an empty artifact store
//! syncs the fingerprinted artifact set before running.

use omgd::jobs::{
    run_gateway, run_grid_remote, run_pool, run_worker_with,
    ArtifactStore, ExperimentKind, GatewayStats, GridReport, JobOutcome,
    JobQueue, JobSpec, ListenOptions, WorkerOptions,
};
use omgd::config::RunConfig;
use omgd::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("omgd-remote-test-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A finetune cell whose artifacts dir is deliberately nonexistent, so
/// the gateway's fingerprint is deterministically `"absent"` and no
/// sync happens (the stub runners never touch artifacts anyway).
fn spec(seed: u64) -> JobSpec {
    let mut cfg = RunConfig::default();
    cfg.seed = seed;
    cfg.artifacts_dir = "/nonexistent/omgd-remote-test".into();
    JobSpec {
        kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 1 },
        cfg,
    }
}

/// Deterministic stub outcome, a pure function of the spec.
fn stub_outcome(spec: &JobSpec) -> JobOutcome {
    JobOutcome {
        final_metric: spec.cfg.seed as f64 + 0.5,
        tail_loss: 0.25,
        steps: 2,
        train_secs: 0.0,
        loss_series: vec![(0, 1.0)],
        eval_series: vec![],
    }
}

/// The same grid on a local pool — the byte-identical baseline.
fn local_report(specs: Vec<JobSpec>, workers: usize) -> GridReport {
    let queue = JobQueue::bounded(specs.len().max(1));
    for s in specs {
        queue.push(s, 0).unwrap();
    }
    queue.close();
    let results = run_pool(&queue, workers, |_wid| {
        |s: &JobSpec| Ok((stub_outcome(s), false))
    });
    GridReport::new(results)
}

fn csv_bytes(report: &GridReport, tag: &str) -> Vec<u8> {
    let dir = tmp_dir(tag);
    let path = dir.join("grid.csv");
    report.write_csv(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    bytes
}

/// Start a coordinator-only gateway (no local workers, no cache) on a
/// free loopback port.
fn start_gateway(
    lopts: ListenOptions,
) -> (SocketAddr, std::thread::JoinHandle<GatewayStats>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        run_gateway(listener, 0, &lopts, None, |_wid| {
            |_s: &JobSpec| -> anyhow::Result<(JobOutcome, bool)> {
                unreachable!("coordinator-only gateway has no local pool")
            }
        })
        .unwrap()
    });
    (addr, handle)
}

fn worker_opts(addr: SocketAddr, id: &str, tag: &str) -> WorkerOptions {
    WorkerOptions {
        connect: addr.to_string(),
        workers: 2,
        worker_id: id.to_string(),
        cache_dir: Some(
            tmp_dir(&format!("{tag}-cache-{id}"))
                .to_string_lossy()
                .into_owned(),
        ),
        store_dir: Some(
            tmp_dir(&format!("{tag}-store-{id}"))
                .to_string_lossy()
                .into_owned(),
        ),
        force: false,
        max_failures: 50,
        ..WorkerOptions::default()
    }
}

/// One raw HTTP/1.1 round trip (the manual-protocol side of the tests).
fn http(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: omgd-test\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut r = BufReader::new(stream);
    let mut status_line = String::new();
    r.read_line(&mut status_line).unwrap();
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .unwrap();
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        if h.trim_end().is_empty() {
            break;
        }
    }
    let mut body = String::new();
    r.read_to_string(&mut body).unwrap();
    (status, body)
}

fn shutdown(addr: SocketAddr) {
    let (status, body) = http(addr, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    assert!(body.contains("draining"));
}

#[test]
fn remote_grid_on_two_workers_matches_local_pool_byte_for_byte() {
    let lopts = ListenOptions {
        poll_secs: 2,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    let specs: Vec<JobSpec> = (0..6).map(spec).collect();
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "base-a");

    let (report, wa, wb) = std::thread::scope(|s| {
        let a = s.spawn(|| {
            run_worker_with(&worker_opts(addr, "w-a", "two"), |_wid| {
                |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                    Ok(stub_outcome(s))
                }
            })
            .unwrap()
        });
        let b = s.spawn(|| {
            run_worker_with(&worker_opts(addr, "w-b", "two"), |_wid| {
                |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                    Ok(stub_outcome(s))
                }
            })
            .unwrap()
        });
        let report =
            run_grid_remote(&addr.to_string(), specs, None).unwrap();
        // Grid done: drain the gateway so both agents exit.
        shutdown(addr);
        (report, a.join().unwrap(), b.join().unwrap())
    });

    assert_eq!(report.n_jobs(), 6);
    assert_eq!(report.n_failed(), 0);
    let remote_csv = csv_bytes(&report, "remote-a");
    assert_eq!(
        remote_csv, baseline,
        "remote aggregate must be byte-identical to the local pool's"
    );
    // Both ends agree on the accounting: every job ran exactly once,
    // somewhere.
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 6);
    assert_eq!(stats.jobs.failed, 0);
    assert_eq!(stats.remote.leased, 6);
    assert_eq!(stats.remote.conflicts, 0);
    assert_eq!(wa.done + wb.done, 6);
    assert_eq!(wa.failed + wb.failed, 0);
}

#[test]
fn killed_worker_mid_lease_is_requeued_and_its_late_result_rejected() {
    let lopts = ListenOptions {
        poll_secs: 2,
        lease_secs: 1, // expire fast: the zombie never renews
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    let specs: Vec<JobSpec> = (10..13).map(spec).collect();
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "base-b");

    let (report, zombie_seq, stolen) = std::thread::scope(|s| {
        let grid = s.spawn({
            let specs = specs.clone();
            move || {
                run_grid_remote(&addr.to_string(), specs, None).unwrap()
            }
        });
        // Wait until the session has queued work.
        let mut queued = false;
        for _ in 0..400 {
            let (status, body) = http(addr, "GET", "/healthz", "");
            assert_eq!(status, 200);
            let j = Json::parse(&body).unwrap();
            if j.at("queue_len").as_usize().unwrap_or(0) >= 1 {
                queued = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(queued, "grid session never queued work");
        // A "worker" leases one job and dies (never renews, never
        // reports) — simulated by simply holding the lease reply.
        let (status, body) = http(
            addr,
            "POST",
            "/work/lease",
            "{\"worker\":\"zombie\",\"artifacts\":[]}",
        );
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        let lease = j.get("lease").expect("zombie got a lease");
        let zombie_seq = lease.at("seq").as_usize().unwrap();
        let stolen_seed = lease
            .at("spec")
            .at("seed")
            .as_usize()
            .expect("leases carry the full wire spec");
        // Now a healthy agent joins; after ~1s the zombie's lease
        // expires and its job is re-dispatched to this agent.
        let healthy = s.spawn(|| {
            run_worker_with(&worker_opts(addr, "w-ok", "kill"), |_wid| {
                |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                    Ok(stub_outcome(s))
                }
            })
            .unwrap()
        });
        let report = grid.join().unwrap();
        // The zombie reports its result *after* re-dispatch completed:
        // the gateway must reject it as a conflict, not double-deliver.
        let late = format!(
            "{{\"worker\":\"zombie\",\"status\":\"done\",\
             \"cached\":false,\"secs\":9.9,\"outcome\":\
             {{\"final_metric\":999.0,\"tail_loss\":9.0,\"steps\":9,\
             \"train_secs\":9.0,\"loss_series\":[],\
             \"eval_series\":[]}}}}"
        );
        let (status, body) = http(
            addr,
            "POST",
            &format!("/work/{zombie_seq}/result"),
            &late,
        );
        assert_eq!(status, 409, "late result must conflict: {body}");
        shutdown(addr);
        let _ = healthy.join().unwrap();
        (report, zombie_seq, stolen_seed)
    });

    assert_eq!(report.n_jobs(), 3);
    assert_eq!(
        report.n_failed(),
        0,
        "the re-dispatched job completed despite the dead worker"
    );
    // The lease the zombie held really was one of this grid's cells.
    assert!(zombie_seq < 3, "seq {zombie_seq} out of range");
    assert!((10..13).contains(&stolen), "leased seed {stolen}");
    // And the aggregate is still byte-identical — 999.0 never leaked.
    let remote_csv = csv_bytes(&report, "remote-b");
    assert_eq!(remote_csv, baseline);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 3);
    assert!(stats.remote.requeued >= 1, "expiry re-dispatched the job");
    assert!(stats.remote.conflicts >= 1, "late result was rejected");
}

#[test]
fn empty_store_worker_syncs_artifacts_by_fingerprint_before_running() {
    let lopts = ListenOptions {
        poll_secs: 2,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    // A fake-but-real artifact set on the "gateway" machine.
    let art_dir = tmp_dir("sync-artifacts");
    std::fs::write(art_dir.join("fakemod.json"), b"{\"manifest\":1}")
        .unwrap();
    std::fs::write(
        art_dir.join("fakemod.train.hlo.txt"),
        b"HloModule train\n",
    )
    .unwrap();
    std::fs::write(
        art_dir.join("fakemod.init.bin"),
        [0u8, 1, 2, 253, 254, 255, 10, 13],
    )
    .unwrap();
    std::fs::write(art_dir.join("unrelated.json"), b"{}").unwrap();

    let mk = |seed: u64| {
        let mut s = spec(seed);
        s.cfg.model = "fakemod".into();
        s.cfg.artifacts_dir = art_dir.to_string_lossy().into_owned();
        s
    };
    let specs = vec![mk(0), mk(1)];
    let expect_fp = omgd::jobs::artifact_fingerprint(&specs[0].cfg);
    assert_ne!(expect_fp, "absent", "fixture artifacts must fingerprint");

    // The stub runner records the artifacts dir each job actually saw
    // and verifies the synced bytes match the originals.
    let seen: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let art_src = art_dir.clone();
    let (report, wstats) = std::thread::scope(|s| {
        let worker = s.spawn(|| {
            let mut opts = worker_opts(addr, "w-sync", "sync");
            opts.workers = 1; // serialize: exactly one sync expected
            run_worker_with(&opts, |_wid| {
                |js: &JobSpec| -> anyhow::Result<JobOutcome> {
                    let dir = PathBuf::from(&js.cfg.artifacts_dir);
                    assert_ne!(
                        dir, art_src,
                        "worker must run against its own synced copy"
                    );
                    for name in
                        ["fakemod.json", "fakemod.train.hlo.txt",
                         "fakemod.init.bin"]
                    {
                        let synced = std::fs::read(dir.join(name))
                            .expect("synced file exists");
                        let orig =
                            std::fs::read(art_src.join(name)).unwrap();
                        assert_eq!(synced, orig, "{name} byte-identical");
                    }
                    assert!(
                        !dir.join("unrelated.json").exists(),
                        "foreign files are not synced"
                    );
                    seen.lock()
                        .unwrap()
                        .push(js.cfg.artifacts_dir.clone());
                    Ok(stub_outcome(js))
                }
            })
            .unwrap()
        });
        let report =
            run_grid_remote(&addr.to_string(), specs.clone(), None)
                .unwrap();
        shutdown(addr);
        (report, worker.join().unwrap())
    });

    assert_eq!(report.n_jobs(), 2);
    assert_eq!(report.n_failed(), 0, "both synced cells ran");
    assert_eq!(wstats.synced, 1, "one artifact set, fetched once");
    let seen = seen.into_inner().unwrap();
    assert_eq!(seen.len(), 2);
    assert_eq!(seen[0], seen[1], "both jobs share the synced copy");
    assert!(
        seen[0].contains(&expect_fp),
        "store keys by the gateway fingerprint: {} vs {expect_fp}",
        seen[0]
    );
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 2);
    std::fs::remove_dir_all(&art_dir).ok();
}

/// `GET /artifacts/<fp>` error shapes: unknown fingerprints 404; a
/// fingerprint whose files changed since the lease 409s ("stale").
#[test]
fn artifact_endpoint_rejects_unknown_and_stale_fingerprints() {
    let lopts = ListenOptions {
        poll_secs: 1,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    let (status, body) =
        http(addr, "GET", "/artifacts/0123456789abcdef", "");
    assert_eq!(status, 404, "unknown fingerprint: {body}");

    let art_dir = tmp_dir("stale-artifacts");
    std::fs::write(art_dir.join("m.json"), b"v1").unwrap();
    let mut s = spec(0);
    s.cfg.model = "m".into();
    s.cfg.artifacts_dir = art_dir.to_string_lossy().into_owned();
    let fp = omgd::jobs::artifact_fingerprint(&s.cfg);

    // Submit + manually lease so the gateway registers the fingerprint.
    let grid = {
        let specs = vec![s];
        std::thread::spawn(move || {
            // The job will be completed manually below.
            run_grid_remote(&addr.to_string(), specs, None)
        })
    };
    let mut lease = None;
    for _ in 0..50 {
        let (status, body) = http(
            addr,
            "POST",
            "/work/lease",
            "{\"worker\":\"manual\",\"artifacts\":[]}",
        );
        assert_eq!(status, 200);
        let j = Json::parse(&body).unwrap();
        if j.get("lease").is_some() {
            lease = Some(j);
            break;
        }
    }
    let lease = lease.expect("grid job never became leasable");
    let leased = lease.get("lease").unwrap();
    assert_eq!(leased.at("afp").as_str(), Some(fp.as_str()));

    // Regenerate the artifact after the lease: same name, new content.
    std::thread::sleep(Duration::from_millis(20));
    std::fs::write(art_dir.join("m.json"), b"v2-regenerated").unwrap();
    let (status, body) = http(addr, "GET", &format!("/artifacts/{fp}"), "");
    assert_eq!(status, 409, "stale fingerprint must 409: {body}");
    assert!(body.contains("stale"));

    // Finish the leased job so the grid session drains.
    let seq = leased.at("seq").as_usize().unwrap();
    let done = "{\"worker\":\"manual\",\"status\":\"failed\",\
                \"secs\":0.1,\"error\":\"fixture\"}";
    let (status, _) =
        http(addr, "POST", &format!("/work/{seq}/result"), done);
    assert_eq!(status, 200);
    let report = grid.join().unwrap().unwrap();
    assert_eq!(report.n_failed(), 1);
    shutdown(addr);
    gateway.join().unwrap();
    std::fs::remove_dir_all(&art_dir).ok();
}

/// Tentpole acceptance: with two workers whose artifact stores cover
/// disjoint halves of a mixed grid, affinity leasing routes every cell
/// to the worker that already holds its artifact set — zero redundant
/// syncs, `remote.affinity` visible in `/stats`, and the aggregate
/// still byte-identical to a local pool. Placement is deterministic:
/// the whole grid is queued before either worker polls, and each
/// worker's `--max-jobs` budget equals exactly its half (which also
/// exercises the lifecycle knob end to end).
#[test]
fn affinity_routes_cells_to_artifact_holders_with_zero_resync() {
    let lopts = ListenOptions {
        poll_secs: 2,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);

    // Two disjoint artifact sets ("models") on the gateway host.
    let art = tmp_dir("aff-artifacts");
    std::fs::write(art.join("ma.json"), b"{\"m\":\"a\"}").unwrap();
    std::fs::write(art.join("mb.json"), b"{\"m\":\"b\"}").unwrap();
    let mk = |model: &str, seed: u64| {
        let mut s = spec(seed);
        s.cfg.model = model.to_string();
        s.cfg.artifacts_dir = art.to_string_lossy().into_owned();
        s
    };
    // ma cells lead the queue: a cache-blind scheduler's oldest-first
    // pop would hand worker B an ma cell (and force a sync).
    let specs =
        vec![mk("ma", 0), mk("ma", 1), mk("mb", 2), mk("mb", 3)];
    let fp_a = omgd::jobs::artifact_fingerprint(&specs[0].cfg);
    let fp_b = omgd::jobs::artifact_fingerprint(&specs[2].cfg);
    assert_ne!(fp_a, "absent");
    assert_ne!(fp_a, fp_b);
    let baseline = csv_bytes(&local_report(specs.clone(), 1), "aff-base");

    // Pre-seed each worker's store with ITS half, as if synced on an
    // earlier grid; each agent runs one thread with a 2-job budget.
    let mut opts_a = worker_opts(addr, "w-aff-a", "aff");
    opts_a.workers = 1;
    opts_a.max_jobs = 2;
    let mut opts_b = worker_opts(addr, "w-aff-b", "aff");
    opts_b.workers = 1;
    opts_b.max_jobs = 2;
    let store_a = ArtifactStore::open(opts_a.store_dir.as_deref()).unwrap();
    store_a
        .ensure(&fp_a, || omgd::jobs::sync::pack(&art, "ma"))
        .unwrap();
    let store_b = ArtifactStore::open(opts_b.store_dir.as_deref()).unwrap();
    store_b
        .ensure(&fp_b, || omgd::jobs::sync::pack(&art, "mb"))
        .unwrap();

    let (report, wa, wb, affinity) = std::thread::scope(|s| {
        let grid = s.spawn({
            let specs = specs.clone();
            move || {
                run_grid_remote(&addr.to_string(), specs, None).unwrap()
            }
        });
        // Every cell queued before the first poll → every scan sees
        // the full grid.
        let mut queued = false;
        for _ in 0..400 {
            let (status, body) = http(addr, "GET", "/healthz", "");
            assert_eq!(status, 200);
            let j = Json::parse(&body).unwrap();
            if j.at("queue_len").as_usize().unwrap_or(0) == 4 {
                queued = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(queued, "grid session never queued all 4 cells");
        let run = |opts: WorkerOptions| {
            move || {
                run_worker_with(&opts, |_wid| {
                    |s: &JobSpec| -> anyhow::Result<JobOutcome> {
                        Ok(stub_outcome(s))
                    }
                })
                .unwrap()
            }
        };
        let a = s.spawn(run(opts_a));
        let b = s.spawn(run(opts_b));
        let report = grid.join().unwrap();
        // Snapshot /stats before shutdown resets nothing — affinity
        // is hub-lifetime, but the gateway exits after drain.
        let (status, body) = http(addr, "GET", "/stats", "");
        assert_eq!(status, 200);
        let affinity = Json::parse(&body)
            .unwrap()
            .at("remote")
            .at("affinity")
            .as_usize();
        shutdown(addr);
        (report, a.join().unwrap(), b.join().unwrap(), affinity)
    });

    assert_eq!(report.n_jobs(), 4);
    assert_eq!(report.n_failed(), 0);
    assert_eq!(
        (wa.leased, wb.leased),
        (2, 2),
        "the --max-jobs budget split the grid evenly"
    );
    assert_eq!(
        (wa.synced, wb.synced),
        (0, 0),
        "affinity placement makes every sync redundant"
    );
    assert_eq!(affinity, Some(4), "every lease was an affinity match");
    let remote_csv = csv_bytes(&report, "aff-remote");
    assert_eq!(remote_csv, baseline);
    let stats = gateway.join().unwrap();
    assert_eq!(stats.jobs.done, 4);
    assert_eq!(stats.remote.leased, 4);
    assert_eq!(stats.remote.affinity, 4);
    std::fs::remove_dir_all(&art).ok();
}

/// Lifecycle: an agent pointed at an idle gateway exits on its own via
/// `--idle-exit`, without waiting for a drain signal.
#[test]
fn idle_worker_exits_via_idle_exit_without_drain() {
    let lopts = ListenOptions {
        poll_secs: 1,
        ..ListenOptions::default()
    };
    let (addr, gateway) = start_gateway(lopts);
    let mut opts = worker_opts(addr, "w-idle", "idle");
    opts.workers = 1;
    opts.idle_exit_secs = 1;
    let t0 = Instant::now();
    let stats = run_worker_with(&opts, |_wid| {
        |_s: &JobSpec| -> anyhow::Result<JobOutcome> {
            unreachable!("no jobs were ever submitted")
        }
    })
    .unwrap();
    assert_eq!(stats.leased, 0);
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "idle-exit must beat the drain-or-die default"
    );
    shutdown(addr);
    gateway.join().unwrap();
}

/// Sanity net for the aggregation math used above: metrics grouped per
/// method over a mixed local report (keeps `mean_metric_by` honest for
/// remote-built reports too).
#[test]
fn remote_reports_aggregate_like_local_ones() {
    let specs: Vec<JobSpec> = (0..4).map(spec).collect();
    let rep = local_report(specs, 2);
    let by: BTreeMap<String, f64> =
        rep.mean_metric_by(|r| r.spec.cfg.method.name().to_string());
    assert_eq!(by.len(), 1);
    // seeds 0..4 → metrics 0.5,1.5,2.5,3.5 → mean 2.0
    assert!((by.values().next().unwrap() - 2.0).abs() < 1e-12);
}
