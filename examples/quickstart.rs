//! Quickstart: the OMGD public API in ~60 lines.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the AOT artifacts, fine-tunes the bundled MLP classifier on a
//! synthetic CoLA-like task twice — once with plain LISA, once with the
//! paper's LISA-WOR — and prints the comparison.

use omgd::config::{Method, OptFamily};
use omgd::data::GLUE_LIKE_TASKS;
use omgd::experiments::{finetune_cell, load_bundle, task_for,
                        FinetuneSetup};
use omgd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. PJRT CPU runtime + AOT bundle (HLO compiled once, up front).
    let rt = Runtime::cpu()?;
    let bundle = load_bundle(&rt, "mlp-glue")?;
    println!(
        "loaded {} ({} params, {} middle layers)",
        bundle.man.name,
        bundle.man.total_len,
        bundle.man.middle_layers().len()
    );

    // 2. A synthetic GLUE-like task (fixed seed ⇒ same data each run).
    let task = task_for(&bundle, &GLUE_LIKE_TASKS[0]);
    println!("task {}: {} train / {} test samples", task.name,
             task.n_train(), task.test_x.len());

    // 3. Fine-tune with LISA (i.i.d. layers) vs LISA-WOR (Algorithm 2).
    let setup = FinetuneSetup { epochs: 10, gamma: 4, period: 1,
                                ..FinetuneSetup::default() };
    for method in [Method::Lisa, Method::LisaWor] {
        let out = finetune_cell(&bundle, &task, method, &setup,
                                OptFamily::AdamW)?;
        println!(
            "{:10} test acc {:.2}%  tail loss {:.4}  ({:.1} steps/s)",
            method.name(),
            out.final_metric,
            out.tail_loss(20),
            out.steps_per_sec
        );
    }
    println!("\nsame data, same budget — the wor traversal is the only \
              difference.");
    Ok(())
}
