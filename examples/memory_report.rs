//! Memory report: the paper's Table 8 / Fig. 6 analytic breakdown for
//! LLaMA-7B and GPT-2-124M, plus the same residency model applied to the
//! bundled AOT configs (so the numbers connect to what the trainer
//! actually holds).
//!
//!   cargo run --release --example memory_report

use omgd::bench::TablePrinter;
use omgd::experiments::{artifacts_present, load_bundle};
use omgd::memory::{breakdown, ArchSpec, MemBreakdown, MemPolicy};
use omgd::runtime::Runtime;

fn report(arch: &ArchSpec, rank: usize, gamma: usize) {
    let mut table = TablePrinter::new(&[
        "Method", "Model", "Grads", "Optimizer", "Others", "Total",
        "vs full",
    ]);
    let full = breakdown(arch, MemPolicy::Full).total();
    for (name, policy) in [
        ("Full params", MemPolicy::Full),
        ("GaLore/GoLore", MemPolicy::Galore(rank)),
        ("LISA/LISA-wor", MemPolicy::Lisa(gamma)),
    ] {
        let b = breakdown(arch, policy);
        table.row(vec![
            name.into(),
            format!("{:.2}", MemBreakdown::gb(b.model)),
            format!("{:.2}", MemBreakdown::gb(b.gradients)),
            format!("{:.2}", MemBreakdown::gb(b.optimizer)),
            format!("{:.2}", MemBreakdown::gb(b.others)),
            format!("{:.2}", MemBreakdown::gb(b.total())),
            format!("-{:.0}%",
                    100.0 * (1.0 - b.total() as f64 / full as f64)),
        ]);
    }
    table.print(&format!(
        "{} memory breakdown (GB; rank={rank}, γ={gamma})",
        arch.name
    ));
}

fn main() -> anyhow::Result<()> {
    report(&ArchSpec::llama_7b(), 128, 2);
    report(&ArchSpec::gpt2_124m(), 128, 3);

    // Our own AOT configs through the identical model.
    let rt = Runtime::cpu()?;
    for model in ["gpt-tiny", "gpt-nano", "mlp-glue"] {
        if !artifacts_present(model) {
            continue;
        }
        let bundle = load_bundle(&rt, model)?;
        let arch = ArchSpec::from_manifest(&bundle.man);
        let gamma = 2.min(arch.n_middle.max(1));
        report(&arch, 8, gamma);
    }
    Ok(())
}
