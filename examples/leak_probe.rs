//! Memory-regression probe: RSS must stay flat across repeated HLO
//! executions. Guards against the `xla` crate's literal-`execute` input
//! leak we work around in `runtime` (rust-owned buffers + `execute_b`);
//! before the fix this probe grew ~58 MB/update and long pre-training
//! runs were OOM-killed.
//!
//!   cargo run --release --example leak_probe

use omgd::experiments::*;
use omgd::runtime::Runtime;

fn rss_kb() -> usize {
    let s = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    s.lines()
        .find(|l| l.starts_with("VmRSS"))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let model = if artifacts_present("gpt-tiny") { "gpt-tiny" }
                else { "gpt-nano" };
    let bundle = load_bundle(&rt, model)?;
    let n = bundle.padded_len();
    let mut p = bundle.init_params()?;
    let g = vec![0.01f32; n];
    let mask = vec![1.0f32; n];
    let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let hp = [1e-3f32, 0.9, 0.999, 1e-8, 0.01, 0.1, 0.001, 0.0];

    println!("probe target {model} (P={n}); start RSS {} MB",
             rss_kb() / 1024);
    let base = rss_kb();
    for i in 0..30 {
        bundle.adamw_update(&mut p, &g, &mask, &mut m, &mut v, &hp)?;
        if i % 10 == 9 {
            println!("after update {:>2}: RSS {} MB", i + 1,
                     rss_kb() / 1024);
        }
    }
    let corpus = pretrain_corpus(&bundle, 16);
    let idx: Vec<usize> = (0..bundle.man.data.batch).collect();
    let (x, y) = corpus.pack(&idx, bundle.man.data.batch);
    for i in 0..30 {
        let _ = bundle.train_step_lm(&p, &x, &y)?;
        if i % 10 == 9 {
            println!("after train  {:>2}: RSS {} MB", i + 1,
                     rss_kb() / 1024);
        }
    }
    let grown = rss_kb().saturating_sub(base);
    // Allow arena warmup, flag real leaks (>1 GB over 60 executions).
    if grown > 1_000_000 {
        anyhow::bail!("RSS grew {} MB across 60 executions — leak!",
                      grown / 1024);
    }
    println!("leak probe OK (+{} MB over 60 executions)", grown / 1024);
    Ok(())
}
