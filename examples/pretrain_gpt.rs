//! End-to-end driver (the repo's E2E validation run): pre-train the
//! transformer LM through the full three-layer stack —
//!
//!   rust coordinator (LISA-WOR traversal, Algorithm 2)
//!     → PJRT executes the AOT train-step HLO   (L2 JAX model)
//!     → PJRT executes the fused masked-AdamW   (L1 Pallas kernel)
//!
//! on a synthetic Markov corpus, logging the loss curve. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//!   cargo run --release --example pretrain_gpt -- [steps] [model]

use omgd::config::Method;
use omgd::experiments::{artifacts_present, load_bundle, pretrain_cell,
                        pretrain_corpus, results_dir, PretrainSetup};
use omgd::metrics::{CsvCell, CsvWriter};
use omgd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize =
        args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = args
        .get(1)
        .cloned()
        .unwrap_or_else(|| {
            if artifacts_present("gpt-tiny") { "gpt-tiny" } else { "gpt-nano" }
                .to_string()
        });

    let rt = Runtime::cpu()?;
    let bundle = load_bundle(&rt, &model)?;
    let corpus = pretrain_corpus(&bundle, steps);
    println!(
        "e2e pre-train: {} | {} params | {} layers | vocab {} | seq {}",
        model,
        bundle.man.total_len,
        bundle.man.middle_layers().len(),
        bundle.man.data.vocab,
        bundle.man.data.seq
    );
    println!(
        "corpus: {} windows | unigram H {:.3} nats | bigram H {:.3} nats \
         (loss should land between)",
        corpus.n_samples(),
        corpus.unigram_entropy(),
        corpus.bigram_entropy()
    );

    let setup = PretrainSetup {
        model: model.clone(),
        steps,
        gamma: 3.min(bundle.man.middle_layers().len()),
        period: (steps / 15).max(5),
        eval_every: (steps / 12).max(10),
        ..PretrainSetup::default()
    };
    let out = pretrain_cell(&bundle, Method::LisaWor, &setup)?;

    // Console loss curve (sampled).
    println!("\nstep   train-loss");
    let stride = (out.loss_series.len() / 15).max(1);
    for (i, &(s, l)) in out.loss_series.iter().enumerate() {
        if i % stride == 0 || i + 1 == out.loss_series.len() {
            println!("{s:>5}  {l:.4}");
        }
    }
    for &(s, l, _) in &out.eval_series {
        println!("eval @ {s:>5}: held-out loss {l:.4}");
    }
    println!(
        "\nfinal eval loss {:.4} | start {:.4} → tail {:.4} | \
         {:.2} steps/s | {:.1}s total",
        out.final_metric,
        out.loss_series.first().map(|&(_, l)| l).unwrap_or(f64::NAN),
        out.tail_loss(20),
        out.steps_per_sec,
        out.train_secs
    );

    let path = results_dir().join("e2e_pretrain_loss.csv");
    let mut csv = CsvWriter::create(&path, &["step", "loss"])?;
    for &(s, l) in &out.loss_series {
        csv.row_mixed(&[CsvCell::I(s as i64), CsvCell::F(l)])?;
    }
    csv.flush()?;
    println!("loss curve written to {}", path.display());

    // E2E pass criterion: meaningful learning through the whole stack.
    // Long runs must cross the unigram-entropy floor (context-free
    // model); short smoke runs must at least drop 0.5 nats from init.
    let uni = corpus.unigram_entropy();
    let start = out.loss_series.first().map(|&(_, l)| l).unwrap_or(0.0);
    let tail = out.tail_loss(20);
    if tail < uni {
        println!("E2E OK: tail loss {tail:.3} < unigram entropy {uni:.3} \
                  (model uses context)");
        Ok(())
    } else if tail < start - 0.5 {
        println!("E2E OK (short run): loss fell {start:.3} → {tail:.3}; \
                  unigram floor {uni:.3} needs more steps");
        Ok(())
    } else {
        anyhow::bail!(
            "E2E FAIL: loss {start:.3} → {tail:.3} (unigram {uni:.3})"
        )
    }
}
