//! Fine-tuning method shoot-out on one GLUE-like task: every method in
//! the paper's roster under an identical budget, seed and dataset.
//!
//!   cargo run --release --example finetune_suite -- [task] [epochs]

use omgd::bench::TablePrinter;
use omgd::config::OptFamily;
use omgd::data::GLUE_LIKE_TASKS;
use omgd::experiments::{adamw_method_roster, finetune_cell, load_bundle,
                        task_for, FinetuneSetup};
use omgd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let task_name = args.first().map(|s| s.as_str()).unwrap_or("MNLI");
    let epochs: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(15);

    let spec = GLUE_LIKE_TASKS
        .iter()
        .find(|t| t.name.eq_ignore_ascii_case(task_name))
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown task {task_name}; one of {:?}",
                GLUE_LIKE_TASKS.iter().map(|t| t.name).collect::<Vec<_>>()
            )
        })?;

    let rt = Runtime::cpu()?;
    let bundle = load_bundle(&rt, "mlp-glue")?;
    let task = task_for(&bundle, spec);
    let setup = FinetuneSetup { epochs, gamma: 4, period: 1,
                                ..FinetuneSetup::default() };
    println!("fine-tuning suite on {} ({} epochs, γ={} K={})",
             task.name, epochs, setup.gamma, setup.period);

    let mut table = TablePrinter::new(&[
        "method", "test acc %", "tail loss", "opt-state bytes", "steps/s",
    ]);
    for method in adamw_method_roster() {
        let out = finetune_cell(&bundle, &task, method, &setup,
                                OptFamily::AdamW)?;
        // Residency estimate: LISA-family keeps states only for active
        // coords; full keeps everything (see memory model for exact GB).
        let state = match method.name() {
            "full" => bundle.man.total_len * 8,
            _ => bundle.man.total_len * 2, // coarse: ~γ/N_L of full
        };
        table.row(vec![
            method.name().into(),
            format!("{:.2}", out.final_metric),
            format!("{:.4}", out.tail_loss(20)),
            format!("{state}"),
            format!("{:.1}", out.steps_per_sec),
        ]);
    }
    table.print(&format!("method comparison — {}", task.name));
    Ok(())
}
