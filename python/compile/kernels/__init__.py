"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from . import ref  # noqa: F401
from .masked_adamw import masked_adamw  # noqa: F401
from .masked_sgdm import masked_sgdm  # noqa: F401
