"""Pallas kernel: fused masked-AdamW update over the flat parameter vector.

This is the L1 hot-spot of the reproduction: one streaming pass that fuses
mask application (gradient gating + OMGD rescale), both Adam moment
updates, bias correction, and the decoupled-weight-decay parameter step.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the flat vector is tiled
into ``block``-sized chunks; each grid step stages six input streams
(p, g, mask, m, v + the replicated hyper-parameter block) into VMEM and
writes three output streams. The kernel is purely elementwise (VPU, no
MXU), hence bandwidth-bound; ``block`` is chosen so that
``9 × block × 4 B`` plus double-buffering fits comfortably in VMEM.

On this testbed the kernel is lowered with ``interpret=True`` so the HLO
runs on the CPU PJRT client — structure (single pass, no recompute) is
preserved; absolute TPU performance is estimated analytically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default block: 64 Ki elements → 9 × 256 KiB = 2.25 MiB VMEM traffic per
# grid step, ≪ 16 MiB VMEM even with double buffering.
DEFAULT_BLOCK = 65536


def _adamw_kernel(hp_ref, p_ref, g_ref, mask_ref, m_ref, v_ref,
                  p_out, m_out, v_out):
    """One block of the fused masked-AdamW update (all refs in VMEM)."""
    lr = hp_ref[ref.HP_LR]
    b1 = hp_ref[ref.HP_B1]
    b2 = hp_ref[ref.HP_B2]
    eps = hp_ref[ref.HP_EPS]
    wd = hp_ref[ref.HP_WD]
    bc1 = hp_ref[ref.HP_BC1]
    bc2 = hp_ref[ref.HP_BC2]

    p = p_ref[...]
    mask = mask_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    active = mask != 0.0

    # Mask gates AND rescales the raw gradient (eq. 3 / Algorithm 2 scale).
    gm = mask * g_ref[...]
    m_new = jnp.where(active, b1 * m + (1.0 - b1) * gm, m)
    v_new = jnp.where(active, b2 * v + (1.0 - b2) * gm * gm, v)
    mhat = m_new / bc1
    vhat = v_new / bc2
    step = lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)

    p_out[...] = jnp.where(active, p - step, p)
    m_out[...] = m_new
    v_out[...] = v_new


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def masked_adamw(p, g, mask, m, v, hp, *, block=DEFAULT_BLOCK,
                 interpret=True):
    """Fused masked-AdamW over f32[P] flat states.

    ``P`` must be a multiple of ``block`` (the AOT manifest pads the flat
    parameter vector accordingly; padding lanes carry mask == 0 so they
    are provably untouched).
    """
    (n,) = p.shape
    if n % block != 0:
        raise ValueError(f"flat length {n} not a multiple of block {block}")
    grid = (n // block,)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    # The hyper-parameter vector is replicated to every grid step.
    hp_spec = pl.BlockSpec((ref.ADAMW_HP_LEN,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct(p.shape, p.dtype)] * 3
    return pl.pallas_call(
        _adamw_kernel,
        grid=grid,
        in_specs=[hp_spec, vec, vec, vec, vec, vec],
        out_specs=[vec, vec, vec],
        out_shape=out_shape,
        interpret=interpret,
    )(hp, p, g, mask, m, v)
