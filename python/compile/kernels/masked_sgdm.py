"""Pallas kernel: fused masked SGD-with-momentum update (flat params).

Same streaming structure as ``masked_adamw`` with one momentum buffer
instead of two Adam moments: five input streams (hp, p, g, mask, buf) and
two outputs (p', buf'). Supports Nesterov via a hyper-parameter flag so a
single compiled artifact serves both variants.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 65536


def _sgdm_kernel(hp_ref, p_ref, g_ref, mask_ref, buf_ref, p_out, buf_out):
    """One block of the fused masked-SGDM update (all refs in VMEM)."""
    lr = hp_ref[ref.SG_LR]
    mu = hp_ref[ref.SG_MU]
    wd = hp_ref[ref.SG_WD]
    nesterov = hp_ref[ref.SG_NESTEROV]

    p = p_ref[...]
    mask = mask_ref[...]
    buf = buf_ref[...]
    active = mask != 0.0

    gm = mask * g_ref[...] + wd * p
    buf_new = jnp.where(active, mu * buf + gm, buf)
    upd = jnp.where(nesterov != 0.0, gm + mu * buf_new, buf_new)

    p_out[...] = jnp.where(active, p - lr * upd, p)
    buf_out[...] = buf_new


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def masked_sgdm(p, g, mask, buf, hp, *, block=DEFAULT_BLOCK, interpret=True):
    """Fused masked-SGDM over f32[P] flat states (P multiple of block)."""
    (n,) = p.shape
    if n % block != 0:
        raise ValueError(f"flat length {n} not a multiple of block {block}")
    grid = (n // block,)
    vec = pl.BlockSpec((block,), lambda i: (i,))
    hp_spec = pl.BlockSpec((ref.SGDM_HP_LEN,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct(p.shape, p.dtype)] * 2
    return pl.pallas_call(
        _sgdm_kernel,
        grid=grid,
        in_specs=[hp_spec, vec, vec, vec, vec],
        out_specs=[vec, vec],
        out_shape=out_shape,
        interpret=interpret,
    )(hp, p, g, mask, buf)
