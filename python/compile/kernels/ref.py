"""Pure-jnp reference oracles for the Pallas update kernels.

These are the correctness ground truth: the Pallas kernels in
``masked_adamw.py`` / ``masked_sgdm.py`` must match these up to float
tolerance, and the rust native optimizers mirror the same semantics.

Semantics (shared by kernel, oracle, and rust ``optim::masked``):

* ``mask`` is a dense f32 vector over the flat parameter space. A zero
  entry *hard-freezes* the coordinate: parameter AND optimizer state are
  left untouched (this models LISA's frozen layers, whose m/v do not
  decay while frozen). A non-zero entry both selects the coordinate and
  carries the OMGD rescaling factor (``M`` from eq. 3, or ``N_L/γ`` from
  Algorithm 2) which multiplies the raw gradient.
* Bias corrections for AdamW are precomputed by the caller
  (``bc1 = 1 - β₁ᵗ``, ``bc2 = 1 - β₂ᵗ``) so the kernel stays free of
  transcendental ops and the rust side controls the step counter.
"""

from __future__ import annotations

import jax.numpy as jnp

# Layout of the hyper-parameter vector passed to the AdamW kernel.
ADAMW_HP_LEN = 8
HP_LR, HP_B1, HP_B2, HP_EPS, HP_WD, HP_BC1, HP_BC2, HP_UNUSED = range(8)

# Layout of the hyper-parameter vector passed to the SGDM kernel.
SGDM_HP_LEN = 4
SG_LR, SG_MU, SG_WD, SG_NESTEROV = range(4)


def masked_adamw_ref(p, g, mask, m, v, hp):
    """Reference masked-AdamW update.

    Args:
      p, g, mask, m, v: f32[P] flat parameter / gradient / mask / moments.
      hp: f32[ADAMW_HP_LEN] hyper-parameters (see module docstring).
    Returns:
      (p_new, m_new, v_new) each f32[P].
    """
    lr, b1, b2, eps = hp[HP_LR], hp[HP_B1], hp[HP_B2], hp[HP_EPS]
    wd, bc1, bc2 = hp[HP_WD], hp[HP_BC1], hp[HP_BC2]
    active = mask != 0.0
    gm = mask * g
    m_new = jnp.where(active, b1 * m + (1.0 - b1) * gm, m)
    v_new = jnp.where(active, b2 * v + (1.0 - b2) * gm * gm, v)
    mhat = m_new / bc1
    vhat = v_new / bc2
    step = lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    p_new = jnp.where(active, p - step, p)
    return p_new, m_new, v_new


def masked_sgdm_ref(p, g, mask, buf, hp):
    """Reference masked-SGD-with-momentum update (optional Nesterov).

    Matches torch.optim.SGD semantics with weight decay folded into the
    gradient, restricted to active coordinates.

    Args:
      p, g, mask, buf: f32[P].
      hp: f32[SGDM_HP_LEN] = [lr, momentum, weight_decay, nesterov_flag].
    Returns:
      (p_new, buf_new) each f32[P].
    """
    lr, mu, wd, nesterov = hp[SG_LR], hp[SG_MU], hp[SG_WD], hp[SG_NESTEROV]
    active = mask != 0.0
    gm = mask * g + wd * p
    buf_new = jnp.where(active, mu * buf + gm, buf)
    upd = jnp.where(nesterov != 0.0, gm + mu * buf_new, buf_new)
    p_new = jnp.where(active, p - lr * upd, p)
    return p_new, buf_new
