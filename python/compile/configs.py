"""Named AOT configurations.

Each config names the set of artifacts ``aot.py`` emits for it. Rust
selects a config by name via its manifest JSON (``artifacts/<name>.json``).

Sizing notes (CPU testbed): the paper trains GPT-2-124M / ViT-base /
RoBERTa-base on 4 GPUs; on the CPU PJRT client we scale the transformer to
configs that keep a few-hundred-step run in minutes while preserving the
layer structure LISA/OMGD act on. The 124M geometry is still described in
``rust/src/memory`` for the analytic memory experiments.
"""

from __future__ import annotations

from .model import GptConfig, MlpConfig

# Block size for flat-vector padding / the Pallas update kernels.
# 4096 keeps interpret-mode grids small for the tiny configs while the
# kernel itself is block-size agnostic (DESIGN.md records the 64Ki TPU
# choice).
BLOCK = 4096

GPT_CONFIGS = {
    # Unit/integration-test scale: lowers in seconds, runs in milliseconds.
    "gpt-nano": GptConfig(
        name="gpt-nano", vocab=256, seq=64, d_model=64, n_layer=2,
        n_head=2, batch=4,
    ),
    # End-to-end pre-training example scale (~3.3M params).
    "gpt-tiny": GptConfig(
        name="gpt-tiny", vocab=512, seq=128, d_model=192, n_layer=6,
        n_head=6, batch=8,
    ),
    # Larger optional config for perf measurements (~19M params).
    "gpt-small": GptConfig(
        name="gpt-small", vocab=2048, seq=256, d_model=384, n_layer=10,
        n_head=6, batch=4,
    ),
}

MLP_CONFIGS = {
    # GLUE-like synthetic fine-tuning tasks (Tables 3, 5, 6): N_L = 12
    # middle blocks mirrors RoBERTa-base / ViT-base depth.
    "mlp-glue": MlpConfig(
        name="mlp-glue", d_in=64, d_hidden=128, n_mid=12, n_class=4,
        batch=32,
    ),
    # Image-classification substitute (Table 4): wider, 10 classes.
    "mlp-img": MlpConfig(
        name="mlp-img", d_in=192, d_hidden=256, n_mid=6, n_class=10,
        batch=64,
    ),
}

# Configs for which optimizer-update artifacts are emitted (one per padded
# flat length — the kernels are shape-specialized at AOT time).
UPDATE_OPTIMIZERS = ("adamw", "sgdm")
