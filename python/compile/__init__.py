"""Build-time compile path: L1 Pallas kernels + L2 JAX models + AOT driver.

Nothing in this package is imported at runtime; ``make artifacts`` runs it
once to produce ``artifacts/*.hlo.txt`` + manifests for the rust binary.
"""
