"""L2: JAX models over a single *flat* f32 parameter vector.

Every model exposes its parameters as one flat vector so the rust L3
coordinator owns exactly one buffer per state tensor (params, grads, Adam
m/v, momentum) and the L1 masked-update Pallas kernels can stream over
them in a single pass. A :class:`ParamSpec` records the (name, shape,
layer) layout; the same layout is serialized into the AOT manifest so
rust can build tensorwise / layerwise (LISA) masks without ever parsing
HLO.

Models:
  * decoder-only transformer LM (GPT-2 family shape) — pre-training
    experiments (Fig. 5) and the end-to-end example;
  * MLP classifier with a LISA-compatible embed/middle/head layer
    structure — fine-tuning tables (3, 4, 5, 6);
  * linear-regression gradient — the §5.1 illustrative example.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamEntry:
    name: str
    shape: tuple[int, ...]
    layer: str  # "embed" | "block_<i>" | "final" | "head"

    @property
    def size(self) -> int:
        return math.prod(self.shape)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    entries: tuple[ParamEntry, ...]

    @property
    def total(self) -> int:
        return sum(e.size for e in self.entries)

    def padded(self, block: int) -> int:
        return ((self.total + block - 1) // block) * block

    def offsets(self) -> dict[str, tuple[int, int]]:
        out, off = {}, 0
        for e in self.entries:
            out[e.name] = (off, e.size)
            off += e.size
        return out

    def unflatten(self, flat: jax.Array) -> dict[str, jax.Array]:
        """Slice the flat vector into named, shaped parameter arrays."""
        params, off = {}, 0
        for e in self.entries:
            params[e.name] = jax.lax.dynamic_slice(
                flat, (off,), (e.size,)
            ).reshape(e.shape)
            off += e.size
        return params

    def manifest_params(self) -> list[dict]:
        out, off = [], 0
        for e in self.entries:
            out.append(
                {
                    "name": e.name,
                    "shape": list(e.shape),
                    "layer": e.layer,
                    "offset": off,
                    "len": e.size,
                }
            )
            off += e.size
        return out


# ---------------------------------------------------------------------------
# Transformer LM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GptConfig:
    name: str
    vocab: int
    seq: int
    d_model: int
    n_layer: int
    n_head: int
    batch: int

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


def gpt_spec(cfg: GptConfig) -> ParamSpec:
    d, v, s, ff = cfg.d_model, cfg.vocab, cfg.seq, cfg.d_ff
    entries: list[ParamEntry] = [
        ParamEntry("wte", (v, d), "embed"),
        ParamEntry("wpe", (s, d), "embed"),
    ]
    for i in range(cfg.n_layer):
        blk = f"block_{i}"
        entries += [
            ParamEntry(f"{blk}.ln1_g", (d,), blk),
            ParamEntry(f"{blk}.ln1_b", (d,), blk),
            ParamEntry(f"{blk}.attn_qkv_w", (d, 3 * d), blk),
            ParamEntry(f"{blk}.attn_qkv_b", (3 * d,), blk),
            ParamEntry(f"{blk}.attn_proj_w", (d, d), blk),
            ParamEntry(f"{blk}.attn_proj_b", (d,), blk),
            ParamEntry(f"{blk}.ln2_g", (d,), blk),
            ParamEntry(f"{blk}.ln2_b", (d,), blk),
            ParamEntry(f"{blk}.mlp_fc_w", (d, ff), blk),
            ParamEntry(f"{blk}.mlp_fc_b", (ff,), blk),
            ParamEntry(f"{blk}.mlp_proj_w", (ff, d), blk),
            ParamEntry(f"{blk}.mlp_proj_b", (d,), blk),
        ]
    entries += [
        ParamEntry("lnf_g", (d,), "final"),
        ParamEntry("lnf_b", (d,), "final"),
        ParamEntry("head_w", (d, v), "head"),
    ]
    return ParamSpec(tuple(entries))


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(x, qkv_w, qkv_b, proj_w, proj_b, n_head):
    b, s, d = x.shape
    hd = d // n_head
    qkv = x @ qkv_w + qkv_b  # [b, s, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # [b, s, d] -> [b, h, s, hd]
        return t.reshape(b, s, n_head, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(hd)  # [b, h, s, s]
    causal = jnp.tril(jnp.ones((s, s), jnp.bool_))
    att = jnp.where(causal, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return y @ proj_w + proj_b


def gpt_logits(cfg: GptConfig, spec: ParamSpec, flat, tokens):
    """Forward pass: tokens i32[B,S] -> logits f32[B,S,V]."""
    p = spec.unflatten(flat)
    x = p["wte"][tokens] + p["wpe"][None, : tokens.shape[1], :]
    for i in range(cfg.n_layer):
        blk = f"block_{i}"
        h = _layer_norm(x, p[f"{blk}.ln1_g"], p[f"{blk}.ln1_b"])
        x = x + _attention(
            h,
            p[f"{blk}.attn_qkv_w"],
            p[f"{blk}.attn_qkv_b"],
            p[f"{blk}.attn_proj_w"],
            p[f"{blk}.attn_proj_b"],
            cfg.n_head,
        )
        h = _layer_norm(x, p[f"{blk}.ln2_g"], p[f"{blk}.ln2_b"])
        h = jax.nn.gelu(h @ p[f"{blk}.mlp_fc_w"] + p[f"{blk}.mlp_fc_b"])
        x = x + h @ p[f"{blk}.mlp_proj_w"] + p[f"{blk}.mlp_proj_b"]
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x @ p["head_w"]


def _xent(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def gpt_loss(cfg: GptConfig, spec: ParamSpec, flat, tokens, targets):
    return _xent(gpt_logits(cfg, spec, flat, tokens), targets)


def gpt_train_step(cfg: GptConfig, spec: ParamSpec) -> Callable:
    """(flat f32[Ppad], x i32[B,S], y i32[B,S]) -> (loss, grad f32[Ppad])."""

    def step(flat, x, y):
        loss, grad = jax.value_and_grad(
            lambda f: gpt_loss(cfg, spec, f, x, y)
        )(flat)
        return loss, grad

    return step


def gpt_eval_step(cfg: GptConfig, spec: ParamSpec) -> Callable:
    """(flat, x, y) -> (loss,) — held-out perplexity probe."""

    def step(flat, x, y):
        return (gpt_loss(cfg, spec, flat, x, y),)

    return step


def gpt_init(cfg: GptConfig, spec: ParamSpec, seed: int, block: int):
    """GPT-2-style init of the padded flat parameter vector (numpy-free)."""
    key = jax.random.PRNGKey(seed)
    parts = []
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layer)
    for e in spec.entries:
        key, sub = jax.random.split(key)
        if e.name.endswith(("_b", "ln1_b", "ln2_b", "lnf_b")):
            parts.append(jnp.zeros((e.size,), jnp.float32))
        elif e.name.endswith(("ln1_g", "ln2_g", "lnf_g")):
            parts.append(jnp.ones((e.size,), jnp.float32))
        else:
            std = 0.02
            if e.name.endswith(("attn_proj_w", "mlp_proj_w")):
                std *= resid_scale
            parts.append(
                std * jax.random.normal(sub, (e.size,), jnp.float32)
            )
    flat = jnp.concatenate(parts)
    pad = spec.padded(block) - spec.total
    return jnp.pad(flat, (0, pad))


# ---------------------------------------------------------------------------
# MLP classifier (LISA-compatible embed / middle blocks / head structure)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    name: str
    d_in: int
    d_hidden: int
    n_mid: int  # number of middle blocks (LISA's N_L)
    n_class: int
    batch: int


def mlp_spec(cfg: MlpConfig) -> ParamSpec:
    entries = [
        ParamEntry("in_w", (cfg.d_in, cfg.d_hidden), "embed"),
        ParamEntry("in_b", (cfg.d_hidden,), "embed"),
    ]
    for i in range(cfg.n_mid):
        blk = f"block_{i}"
        entries += [
            ParamEntry(f"{blk}.w", (cfg.d_hidden, cfg.d_hidden), blk),
            ParamEntry(f"{blk}.b", (cfg.d_hidden,), blk),
        ]
    entries += [
        ParamEntry("out_w", (cfg.d_hidden, cfg.n_class), "head"),
        ParamEntry("out_b", (cfg.n_class,), "head"),
    ]
    return ParamSpec(tuple(entries))


def mlp_logits(cfg: MlpConfig, spec: ParamSpec, flat, x):
    p = spec.unflatten(flat)
    h = jnp.tanh(x @ p["in_w"] + p["in_b"])
    for i in range(cfg.n_mid):
        blk = f"block_{i}"
        # Residual middle blocks so freezing a block is information-neutral
        # (mirrors transformer blocks under LISA).
        h = h + jnp.tanh(h @ p[f"{blk}.w"] + p[f"{blk}.b"])
    return h @ p["out_w"] + p["out_b"]


def mlp_loss(cfg: MlpConfig, spec: ParamSpec, flat, x, y):
    return _xent(mlp_logits(cfg, spec, flat, x), y)


def mlp_train_step(cfg: MlpConfig, spec: ParamSpec) -> Callable:
    """(flat f32[Ppad], x f32[B,D], y i32[B]) -> (loss, grad f32[Ppad])."""

    def step(flat, x, y):
        loss, grad = jax.value_and_grad(
            lambda f: mlp_loss(cfg, spec, f, x, y)
        )(flat)
        return loss, grad

    return step


def mlp_eval_step(cfg: MlpConfig, spec: ParamSpec) -> Callable:
    """(flat, x, y) -> (loss, n_correct f32)."""

    def step(flat, x, y):
        logits = mlp_logits(cfg, spec, flat, x)
        loss = _xent(logits, y)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        )
        return loss, correct

    return step


def mlp_init(cfg: MlpConfig, spec: ParamSpec, seed: int, block: int):
    key = jax.random.PRNGKey(seed)
    parts = []
    for e in spec.entries:
        key, sub = jax.random.split(key)
        if e.name.endswith("_b") or e.name.endswith(".b"):
            parts.append(jnp.zeros((e.size,), jnp.float32))
        else:
            fan_in = e.shape[0]
            std = 1.0 / math.sqrt(fan_in)
            if e.layer.startswith("block_"):
                # Scale residual branches down so depth doesn't blow up
                # activations (mirrors the GPT-2 residual init).
                std /= math.sqrt(max(cfg.n_mid, 1))
            elif e.name == "out_w":
                # Near-zero head ⇒ near-uniform logits at init.
                std = 0.01
            parts.append(std * jax.random.normal(sub, (e.size,), jnp.float32))
    flat = jnp.concatenate(parts)
    pad = spec.padded(block) - spec.total
    return jnp.pad(flat, (0, pad))


# ---------------------------------------------------------------------------
# §5.1 linear regression
# ---------------------------------------------------------------------------


def linreg_grad(theta, x, y):
    """∇f(θ; x, y) = 2 x (xᵀθ − y) for f = (xᵀθ − y)²; shapes d / d / ()."""
    return (2.0 * (x @ theta - y)) * x


def linreg_step(theta, x, y, eta):
    """One SGD step of the §5.1 problem: θ' = θ − η ∇f(θ; x, y)."""
    return theta - eta * linreg_grad(theta, x, y)
