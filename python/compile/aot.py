"""AOT driver: lower L2 models + L1 kernels to HLO *text* artifacts.

Interchange format is HLO text, not serialized ``HloModuleProto``: jax
≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` rust crate) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Per config this emits into ``artifacts/``:

  <name>.train.hlo.txt    (flat, x, y)    -> (loss, grad)
  <name>.eval.hlo.txt     (flat, x, y)    -> (loss[, n_correct])
  <name>.init.f32bin      initial padded flat parameter vector (raw LE f32)
  <P>.adamw.hlo.txt       (hp, p, g, mask, m, v) -> (p', m', v')   [Pallas]
  <P>.sgdm.hlo.txt        (hp, p, g, mask, buf)  -> (p', buf')     [Pallas]
  <name>.json             manifest: param layout, shapes, artifact files

Update-kernel artifacts are keyed by padded flat length ``P`` and shared
between configs with equal ``P``. Python runs once (`make artifacts`) and
never on the rust request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs as C
from . import model as M
from .kernels import masked_adamw, masked_sgdm
from .kernels import ref as kref


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XLA HLO text via stablehlo (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def _f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_update_kernels(out_dir: str, padded: int, emitted: set) -> dict:
    """Lower the L1 Pallas update kernels for flat length ``padded``.

    CPU-artifact block choice (EXPERIMENTS.md §Perf): interpret-mode
    Pallas costs ~11 ms of fixed overhead *per grid step* on the CPU PJRT
    client (measured at P=2.9M: grid=706 → 7.9 s, grid=1 → 25 ms), so the
    CPU artifacts are lowered with a single block covering the whole flat
    vector. On a real TPU the same kernel would use 64 Ki blocks to fit
    VMEM (DESIGN.md §Hardware-Adaptation); the kernel body is block-size
    agnostic.
    """
    files = {}
    for opt in C.UPDATE_OPTIMIZERS:
        fname = f"{padded}.{opt}.hlo.txt"
        files[opt] = fname
        if (padded, opt) in emitted:
            continue
        emitted.add((padded, opt))
        vec = _f32((padded,))
        block = padded  # grid=1 for the CPU artifact (see docstring)
        if opt == "adamw":
            fn = lambda hp, p, g, mask, m, v: masked_adamw(
                p, g, mask, m, v, hp, block=block, interpret=True
            )
            lowered = jax.jit(fn).lower(
                _f32((kref.ADAMW_HP_LEN,)), vec, vec, vec, vec, vec
            )
        else:
            fn = lambda hp, p, g, mask, buf: masked_sgdm(
                p, g, mask, buf, hp, block=block, interpret=True
            )
            lowered = jax.jit(fn).lower(
                _f32((kref.SGDM_HP_LEN,)), vec, vec, vec, vec
            )
        _write(os.path.join(out_dir, fname), to_hlo_text(lowered))
    return files


def _manifest(out_dir, name, kind, spec, padded, data_shapes, artifacts,
              extra):
    man = {
        "name": name,
        "kind": kind,
        "block": C.BLOCK,
        "total_len": spec.total,
        "padded_len": padded,
        "params": spec.manifest_params(),
        "data": data_shapes,
        "artifacts": artifacts,
    }
    man.update(extra)
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(man, f, indent=1)
    print(f"  wrote {path}")


def _dump_init(out_dir: str, name: str, flat) -> str:
    import numpy as np

    fname = f"{name}.init.f32bin"
    np.asarray(flat, dtype="<f4").tofile(os.path.join(out_dir, fname))
    print(f"  wrote {out_dir}/{fname} ({flat.shape[0]} f32)")
    return fname


def build_gpt(out_dir: str, cfg: M.GptConfig, emitted: set) -> None:
    print(f"[gpt] {cfg.name}: d={cfg.d_model} L={cfg.n_layer} "
          f"V={cfg.vocab} S={cfg.seq} B={cfg.batch}")
    spec = M.gpt_spec(cfg)
    padded = spec.padded(C.BLOCK)
    flat_t = _f32((padded,))
    x_t, y_t = _i32((cfg.batch, cfg.seq)), _i32((cfg.batch, cfg.seq))

    train = jax.jit(M.gpt_train_step(cfg, spec)).lower(flat_t, x_t, y_t)
    _write(os.path.join(out_dir, f"{cfg.name}.train.hlo.txt"),
           to_hlo_text(train))
    evals = jax.jit(M.gpt_eval_step(cfg, spec)).lower(flat_t, x_t, y_t)
    _write(os.path.join(out_dir, f"{cfg.name}.eval.hlo.txt"),
           to_hlo_text(evals))

    upd = lower_update_kernels(out_dir, padded, emitted)
    init_file = _dump_init(
        out_dir, cfg.name, M.gpt_init(cfg, spec, seed=0, block=C.BLOCK)
    )
    _manifest(
        out_dir, cfg.name, "gpt", spec, padded,
        {"batch": cfg.batch, "seq": cfg.seq, "vocab": cfg.vocab},
        {
            "train": f"{cfg.name}.train.hlo.txt",
            "eval": f"{cfg.name}.eval.hlo.txt",
            "init": init_file,
            "update": upd,
        },
        {"n_layer": cfg.n_layer, "d_model": cfg.d_model,
         "n_head": cfg.n_head},
    )


def build_mlp(out_dir: str, cfg: M.MlpConfig, emitted: set) -> None:
    print(f"[mlp] {cfg.name}: d_in={cfg.d_in} h={cfg.d_hidden} "
          f"mid={cfg.n_mid} C={cfg.n_class} B={cfg.batch}")
    spec = M.mlp_spec(cfg)
    padded = spec.padded(C.BLOCK)
    flat_t = _f32((padded,))
    x_t, y_t = _f32((cfg.batch, cfg.d_in)), _i32((cfg.batch,))

    train = jax.jit(M.mlp_train_step(cfg, spec)).lower(flat_t, x_t, y_t)
    _write(os.path.join(out_dir, f"{cfg.name}.train.hlo.txt"),
           to_hlo_text(train))
    evals = jax.jit(M.mlp_eval_step(cfg, spec)).lower(flat_t, x_t, y_t)
    _write(os.path.join(out_dir, f"{cfg.name}.eval.hlo.txt"),
           to_hlo_text(evals))

    upd = lower_update_kernels(out_dir, padded, emitted)
    init_file = _dump_init(
        out_dir, cfg.name, M.mlp_init(cfg, spec, seed=0, block=C.BLOCK)
    )
    _manifest(
        out_dir, cfg.name, "mlp", spec, padded,
        {"batch": cfg.batch, "d_in": cfg.d_in, "n_class": cfg.n_class},
        {
            "train": f"{cfg.name}.train.hlo.txt",
            "eval": f"{cfg.name}.eval.hlo.txt",
            "init": init_file,
            "update": upd,
        },
        {"n_mid": cfg.n_mid, "d_hidden": cfg.d_hidden},
    )


def build_linreg(out_dir: str, d: int = 10) -> None:
    """§5.1 single-sample gradient artifact (runtime integration tests)."""
    print(f"[linreg] d={d}")
    lowered = jax.jit(
        lambda th, x, y: (M.linreg_grad(th, x, y),)
    ).lower(_f32((d,)), _f32((d,)), _f32(()))
    _write(os.path.join(out_dir, "linreg.grad.hlo.txt"), to_hlo_text(lowered))
    with open(os.path.join(out_dir, "linreg.json"), "w") as f:
        json.dump(
            {"name": "linreg", "kind": "linreg", "d": d,
             "artifacts": {"grad": "linreg.grad.hlo.txt"}},
            f, indent=1,
        )


def stamp(out_dir: str) -> None:
    """Content stamp over compile/ inputs so `make artifacts` can skip."""
    h = hashlib.sha256()
    root = os.path.dirname(os.path.abspath(__file__))
    for base, _, names in sorted(os.walk(root)):
        for n in sorted(names):
            if n.endswith(".py"):
                with open(os.path.join(base, n), "rb") as f:
                    h.update(f.read())
    with open(os.path.join(out_dir, "STAMP"), "w") as f:
        f.write(h.hexdigest() + "\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="all",
                    help="comma list of config names, or 'all'/'test'")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.configs == "all":
        gpt_names = list(C.GPT_CONFIGS)
        mlp_names = list(C.MLP_CONFIGS)
    elif args.configs == "test":
        gpt_names, mlp_names = ["gpt-nano"], ["mlp-glue"]
    else:
        wanted = set(args.configs.split(","))
        gpt_names = [n for n in C.GPT_CONFIGS if n in wanted]
        mlp_names = [n for n in C.MLP_CONFIGS if n in wanted]

    emitted: set = set()
    for n in gpt_names:
        build_gpt(args.out, C.GPT_CONFIGS[n], emitted)
    for n in mlp_names:
        build_mlp(args.out, C.MLP_CONFIGS[n], emitted)
    build_linreg(args.out)
    stamp(args.out)
    print("AOT done.")


if __name__ == "__main__":
    sys.exit(main())
