"""AOT pipeline: manifests are consistent and HLO text is well-formed."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))

from compile.configs import BLOCK  # noqa: E402


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--configs", "test"],
        cwd=ROOT, check=True, capture_output=True,
    )
    return out


def _manifest(artifacts, name):
    with open(artifacts / f"{name}.json") as f:
        return json.load(f)


@pytest.mark.parametrize("name", ["gpt-nano", "mlp-glue"])
def test_manifest_layout(artifacts, name):
    man = _manifest(artifacts, name)
    off = 0
    for p in man["params"]:
        assert p["offset"] == off
        shape_len = 1
        for s in p["shape"]:
            shape_len *= s
        assert p["len"] == shape_len
        off += p["len"]
    assert off == man["total_len"]
    assert man["padded_len"] % man["block"] == 0
    assert man["block"] == BLOCK
    assert man["padded_len"] >= man["total_len"]


@pytest.mark.parametrize("name", ["gpt-nano", "mlp-glue"])
def test_artifact_files_exist(artifacts, name):
    man = _manifest(artifacts, name)
    arts = man["artifacts"]
    for key in ("train", "eval", "init"):
        assert (artifacts / arts[key]).exists(), arts[key]
    for opt in ("adamw", "sgdm"):
        assert (artifacts / arts["update"][opt]).exists()


@pytest.mark.parametrize("name", ["gpt-nano", "mlp-glue"])
def test_hlo_text_well_formed(artifacts, name):
    man = _manifest(artifacts, name)
    for key in ("train", "eval"):
        text = (artifacts / man["artifacts"][key]).read_text()
        assert "HloModule" in text
        assert "ENTRY" in text


def test_init_binary_length(artifacts):
    man = _manifest(artifacts, "gpt-nano")
    raw = (artifacts / man["artifacts"]["init"]).read_bytes()
    assert len(raw) == 4 * man["padded_len"]


def test_update_kernel_shared_by_padded_len(artifacts):
    """Update artifacts are keyed by padded length, not config name."""
    man = _manifest(artifacts, "gpt-nano")
    fname = man["artifacts"]["update"]["adamw"]
    assert fname.startswith(str(man["padded_len"]))


def test_stamp_written(artifacts):
    assert (artifacts / "STAMP").exists()


def test_linreg_artifact(artifacts):
    man = _manifest(artifacts, "linreg")
    assert man["d"] == 10
    text = (artifacts / man["artifacts"]["grad"]).read_text()
    assert "HloModule" in text
