"""L1 correctness: Pallas update kernels vs the pure-jnp oracle.

Hypothesis sweeps flat lengths, block sizes, mask sparsity/scale patterns
and hyper-parameters; `assert_allclose` against ``kernels/ref.py`` is the
core correctness signal for everything the rust hot path executes.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_adamw, masked_sgdm
from compile.kernels import ref


def _mk(rng, n):
    return jnp.asarray(rng.normal(size=n).astype(np.float32))


def _mk_mask(rng, n, keep, scale):
    m = (rng.random(n) < keep).astype(np.float32) * scale
    return jnp.asarray(m)


def _adamw_hp(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, wd=0.01, step=10):
    return jnp.asarray(
        [lr, b1, b2, eps, wd, 1.0 - b1**step, 1.0 - b2**step, 0.0],
        jnp.float32,
    )


def _sgdm_hp(lr=0.1, mu=0.9, wd=1e-4, nesterov=0.0):
    return jnp.asarray([lr, mu, wd, nesterov], jnp.float32)


blocks = st.sampled_from([64, 128, 256])
nblocks = st.integers(min_value=1, max_value=4)
keeps = st.sampled_from([0.0, 0.25, 0.5, 1.0])
scales = st.sampled_from([1.0, 2.0, 4.0])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


class TestMaskedAdamW:
    @settings(max_examples=25, deadline=None)
    @given(block=blocks, nb=nblocks, keep=keeps, scale=scales, seed=seeds)
    def test_matches_ref(self, block, nb, keep, scale, seed):
        rng = np.random.default_rng(seed)
        n = block * nb
        p, g, m, v = (_mk(rng, n) for _ in range(4))
        v = jnp.abs(v)  # v must be a running mean of squares
        mask = _mk_mask(rng, n, keep, scale)
        hp = _adamw_hp()
        got = masked_adamw(p, g, mask, m, v, hp, block=block)
        want = ref.masked_adamw_ref(p, g, mask, m, v, hp)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=seeds,
        lr=st.floats(1e-5, 1e-1),
        b1=st.floats(0.0, 0.99),
        b2=st.floats(0.9, 0.9999),
        wd=st.floats(0.0, 0.1),
        step=st.integers(1, 10_000),
    )
    def test_hyperparameter_sweep(self, seed, lr, b1, b2, wd, step):
        rng = np.random.default_rng(seed)
        n = 256
        p, g, m = (_mk(rng, n) for _ in range(3))
        v = jnp.abs(_mk(rng, n))
        mask = _mk_mask(rng, n, 0.5, 2.0)
        hp = _adamw_hp(lr=lr, b1=b1, b2=b2, wd=wd, step=step)
        got = masked_adamw(p, g, mask, m, v, hp, block=128)
        want = ref.masked_adamw_ref(p, g, mask, m, v, hp)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)

    def test_zero_mask_freezes_everything(self):
        rng = np.random.default_rng(0)
        n = 512
        p, g, m = (_mk(rng, n) for _ in range(3))
        v = jnp.abs(_mk(rng, n))
        mask = jnp.zeros(n, jnp.float32)
        p2, m2, v2 = masked_adamw(p, g, mask, m, v, _adamw_hp(), block=128)
        np.testing.assert_array_equal(p2, p)
        np.testing.assert_array_equal(m2, m)
        np.testing.assert_array_equal(v2, v)

    def test_full_mask_equals_plain_adamw(self):
        """mask == 1 everywhere reduces to textbook AdamW."""
        rng = np.random.default_rng(1)
        n = 256
        p, g = _mk(rng, n), _mk(rng, n)
        m, v = jnp.zeros(n), jnp.zeros(n)
        hp = _adamw_hp(step=1)
        p2, m2, v2 = masked_adamw(
            p, g, jnp.ones(n), m, v, hp, block=128
        )
        lr, b1, b2, eps, wd = 1e-3, 0.9, 0.999, 1e-8, 0.01
        m_t = (1 - b1) * np.asarray(g)
        v_t = (1 - b2) * np.asarray(g) ** 2
        mhat = m_t / (1 - b1)
        vhat = v_t / (1 - b2)
        want = np.asarray(p) - lr * (
            mhat / (np.sqrt(vhat) + eps) + wd * np.asarray(p)
        )
        np.testing.assert_allclose(p2, want, rtol=1e-6, atol=1e-7)

    def test_mask_scale_multiplies_gradient(self):
        """mask value M must act exactly like g ← M·g on active coords."""
        rng = np.random.default_rng(2)
        n = 256
        p, g = _mk(rng, n), _mk(rng, n)
        m, v = jnp.zeros(n), jnp.zeros(n)
        hp = _adamw_hp()
        scaled = masked_adamw(
            p, g, 4.0 * jnp.ones(n), m, v, hp, block=128
        )
        direct = masked_adamw(
            p, 4.0 * g, jnp.ones(n), m, v, hp, block=128
        )
        for a, b in zip(scaled, direct):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)

    def test_rejects_non_multiple_length(self):
        n = 300
        z = jnp.zeros(n)
        with pytest.raises(ValueError):
            masked_adamw(z, z, z, z, z, _adamw_hp(), block=128)


class TestMaskedSgdm:
    @settings(max_examples=25, deadline=None)
    @given(
        block=blocks, nb=nblocks, keep=keeps, scale=scales, seed=seeds,
        nesterov=st.sampled_from([0.0, 1.0]),
    )
    def test_matches_ref(self, block, nb, keep, scale, seed, nesterov):
        rng = np.random.default_rng(seed)
        n = block * nb
        p, g, buf = (_mk(rng, n) for _ in range(3))
        mask = _mk_mask(rng, n, keep, scale)
        hp = _sgdm_hp(nesterov=nesterov)
        got = masked_sgdm(p, g, mask, buf, hp, block=block)
        want = ref.masked_sgdm_ref(p, g, mask, buf, hp)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_zero_mask_freezes_everything(self):
        rng = np.random.default_rng(3)
        n = 256
        p, g, buf = (_mk(rng, n) for _ in range(3))
        p2, b2 = masked_sgdm(
            p, g, jnp.zeros(n), buf, _sgdm_hp(), block=128
        )
        np.testing.assert_array_equal(p2, p)
        np.testing.assert_array_equal(b2, buf)

    def test_plain_sgd_when_mu_zero(self):
        """mu=0, wd=0 reduces to θ ← θ − lr·(mask ⊙ g)."""
        rng = np.random.default_rng(4)
        n = 256
        p, g = _mk(rng, n), _mk(rng, n)
        mask = _mk_mask(rng, n, 0.5, 2.0)
        p2, _ = masked_sgdm(
            p, g, mask, jnp.zeros(n), _sgdm_hp(lr=0.1, mu=0.0, wd=0.0),
            block=128,
        )
        want = np.asarray(p) - 0.1 * np.asarray(mask) * np.asarray(g)
        np.testing.assert_allclose(p2, want, rtol=1e-6, atol=1e-7)

    def test_momentum_accumulates_across_steps(self):
        """Two steps with mu=1, full mask: Δ₂ = 2·lr·g for constant g."""
        n = 128
        p = jnp.zeros(n)
        g = jnp.ones(n)
        hp = _sgdm_hp(lr=0.1, mu=1.0, wd=0.0)
        one = jnp.ones(n)
        p1, b1 = masked_sgdm(p, g, one, jnp.zeros(n), hp, block=128)
        p2, _ = masked_sgdm(p1, g, one, b1, hp, block=128)
        np.testing.assert_allclose(np.asarray(p1), -0.1 * np.ones(n),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(p2 - p1), -0.2 * np.ones(n),
                                   rtol=1e-6)


class TestMaskCancellation:
    """Cycle-level property behind Lemma 4.4: with Σⱼ S⁽ʲ⁾ = M·1 and plain
    SGD at fixed θ, the summed masked gradients over a cycle equal the
    summed unmasked gradients (the masking error cancels exactly)."""

    @settings(max_examples=10, deadline=None)
    @given(seed=seeds, m_masks=st.sampled_from([2, 4]))
    def test_cycle_cancellation(self, seed, m_masks):
        rng = np.random.default_rng(seed)
        n = 256
        grads = [_mk(rng, n) for _ in range(8)]
        # Disjoint partition masks with scale M (Remark 4.11 shape).
        perm = rng.permutation(n)
        masks = []
        for j in range(m_masks):
            sel = np.zeros(n, np.float32)
            sel[perm[j::m_masks]] = float(m_masks)
            masks.append(jnp.asarray(sel))
        assert np.allclose(sum(np.asarray(s) for s in masks),
                           m_masks * np.ones(n))
        total_masked = np.zeros(n, np.float32)
        total_plain = np.zeros(n, np.float32)
        for j, s in enumerate(masks):
            for g in grads:
                total_masked += np.asarray(s) * np.asarray(g)
                total_plain += m_masks * np.asarray(g) / m_masks * 1.0
        # Σⱼ Σᵢ S⁽ʲ⁾⊙gᵢ = (Σⱼ S⁽ʲ⁾) ⊙ Σᵢ gᵢ = M·Σᵢ gᵢ
        want = m_masks * sum(np.asarray(g) for g in grads)
        np.testing.assert_allclose(total_masked, want, rtol=1e-4, atol=1e-4)
