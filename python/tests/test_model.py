"""L2 correctness: flat-param models (shapes, init, gradients, loss)."""

import math
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import BLOCK, GPT_CONFIGS, MLP_CONFIGS

GPT = GPT_CONFIGS["gpt-nano"]
MLP = MLP_CONFIGS["mlp-glue"]


class TestParamSpec:
    def test_offsets_contiguous(self):
        spec = M.gpt_spec(GPT)
        off = 0
        for e in spec.manifest_params():
            assert e["offset"] == off
            assert e["len"] == math.prod(e["shape"])
            off += e["len"]
        assert off == spec.total

    def test_padded_multiple_of_block(self):
        for spec in (M.gpt_spec(GPT), M.mlp_spec(MLP)):
            p = spec.padded(BLOCK)
            assert p % BLOCK == 0
            assert 0 <= p - spec.total < BLOCK

    def test_unflatten_round_trip(self):
        spec = M.mlp_spec(MLP)
        flat = jnp.arange(spec.total, dtype=jnp.float32)
        parts = spec.unflatten(flat)
        rebuilt = jnp.concatenate(
            [parts[e.name].reshape(-1) for e in spec.entries]
        )
        np.testing.assert_array_equal(rebuilt, flat)

    def test_layer_tags_cover_lisa_structure(self):
        spec = M.gpt_spec(GPT)
        layers = {e.layer for e in spec.entries}
        assert "embed" in layers and "head" in layers
        mids = sorted(l for l in layers if l.startswith("block_"))
        assert mids == [f"block_{i}" for i in range(GPT.n_layer)]


class TestGpt:
    def setup_method(self):
        self.spec = M.gpt_spec(GPT)
        self.flat = M.gpt_init(GPT, self.spec, seed=0, block=BLOCK)
        key = jax.random.PRNGKey(42)
        self.x = jax.random.randint(
            key, (GPT.batch, GPT.seq), 0, GPT.vocab
        )
        self.y = jnp.roll(self.x, -1, axis=1)

    def test_init_loss_near_uniform(self):
        """Fresh init ⇒ loss ≈ log(vocab) (uniform next-token)."""
        loss = M.gpt_loss(GPT, self.spec, self.flat, self.x, self.y)
        assert abs(float(loss) - math.log(GPT.vocab)) < 0.5

    def test_logits_shape(self):
        logits = M.gpt_logits(GPT, self.spec, self.flat, self.x)
        assert logits.shape == (GPT.batch, GPT.seq, GPT.vocab)

    def test_grad_padding_tail_is_zero(self):
        step = M.gpt_train_step(GPT, self.spec)
        _, grad = step(self.flat, self.x, self.y)
        tail = np.asarray(grad[self.spec.total:])
        np.testing.assert_array_equal(tail, 0.0)

    def test_grad_descends(self):
        step = jax.jit(M.gpt_train_step(GPT, self.spec))
        loss0, grad = step(self.flat, self.x, self.y)
        flat2 = self.flat - 0.5 * grad
        loss1, _ = step(flat2, self.x, self.y)
        assert float(loss1) < float(loss0)

    def test_causality(self):
        """Changing a future token must not change earlier logits."""
        logits0 = M.gpt_logits(GPT, self.spec, self.flat, self.x)
        x2 = self.x.at[:, -1].set((self.x[:, -1] + 1) % GPT.vocab)
        logits1 = M.gpt_logits(GPT, self.spec, self.flat, x2)
        np.testing.assert_allclose(
            logits0[:, :-1], logits1[:, :-1], rtol=1e-5, atol=1e-5
        )


class TestMlp:
    def setup_method(self):
        self.spec = M.mlp_spec(MLP)
        self.flat = M.mlp_init(MLP, self.spec, seed=0, block=BLOCK)
        key = jax.random.PRNGKey(7)
        self.x = jax.random.normal(key, (MLP.batch, MLP.d_in))
        self.y = jax.random.randint(key, (MLP.batch,), 0, MLP.n_class)

    def test_init_loss_near_uniform(self):
        loss = M.mlp_loss(MLP, self.spec, self.flat, self.x, self.y)
        assert abs(float(loss) - math.log(MLP.n_class)) < 0.5

    def test_eval_step_counts(self):
        loss, correct = M.mlp_eval_step(MLP, self.spec)(
            self.flat, self.x, self.y
        )
        assert 0.0 <= float(correct) <= MLP.batch
        assert float(loss) > 0.0

    def test_few_steps_reduce_loss(self):
        step = jax.jit(M.mlp_train_step(MLP, self.spec))
        flat = self.flat
        loss0, _ = step(flat, self.x, self.y)
        for _ in range(20):
            _, g = step(flat, self.x, self.y)
            flat = flat - 0.1 * g
        loss1, _ = step(flat, self.x, self.y)
        assert float(loss1) < float(loss0)

    def test_frozen_block_grad_is_local(self):
        """Zeroing a middle block's slice of a masked update leaves those
        coordinates untouched — layout sanity for LISA masks."""
        step = M.mlp_train_step(MLP, self.spec)
        _, grad = step(self.flat, self.x, self.y)
        offs = self.spec.offsets()
        o, l = offs["block_3.w"]
        assert float(jnp.sum(jnp.abs(grad[o:o + l]))) > 0.0


class TestLinreg:
    def test_grad_formula(self):
        rng = np.random.default_rng(0)
        th = jnp.asarray(rng.normal(size=10).astype(np.float32))
        x = jnp.asarray(rng.normal(size=10).astype(np.float32))
        y = jnp.float32(rng.normal())
        g = M.linreg_grad(th, x, y)
        want = 2.0 * (np.asarray(x) @ np.asarray(th) - float(y)) * \
            np.asarray(x)
        np.testing.assert_allclose(g, want, rtol=1e-5)

    def test_step_moves_toward_solution(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=10).astype(np.float32))
        th_star = jnp.asarray(rng.normal(size=10).astype(np.float32))
        y = x @ th_star
        th = jnp.zeros(10)
        for _ in range(200):
            th = M.linreg_step(th, x, y, 0.01)
        # residual on this sample must vanish
        assert abs(float(x @ th - y)) < 1e-3
